"""CI perf-trajectory gate for the serving engine.

Compares a fresh ``BENCH_serving.json`` (written by
``benchmarks/run.py --json``) against the checked-in baseline and
FAILS (exit 1) when either serving-perf invariant breaks:

1. **relative**: continuous-batching tokens/s must not LOSE to the
   static lock-step server on the mixed-length workload (with a 5%
   tie-break grace for shared-runner noise) — this is the
   machine-independent relation the scheduler exists to win, so it
   gates unconditionally;
2. **trajectory**: continuous-batching tokens/s must not regress more
   than ``--tolerance`` (default 20%) against the checked-in baseline.
   Absolute tokens/s are host-dependent, so the trajectory check
   compares the continuous/static SPEEDUP ratio by default (stable
   across runner generations); pass ``--absolute`` to compare raw
   tokens/s against a baseline recorded on identical hardware;
3. **shared-prefix**: on the long-prompt shared-prefix workload the
   paged pool (prefix sharing on) must not lose to the contiguous
   engine, the prefix cache must record hits, and the paged/contiguous
   speedup ratio must hold its trajectory vs the baseline.

Refreshing the baseline after an intentional change: copy the CI
artifact (or a local ``--json`` run's output) over
``benchmarks/baselines/BENCH_serving.json`` and commit it.

Usage:
    python benchmarks/check_serving_regression.py \
        --current BENCH_serving.json \
        [--baseline benchmarks/baselines/BENCH_serving.json] \
        [--tolerance 0.2] [--absolute]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).parent / "baselines" / "BENCH_serving.json"


def check(current: dict, baseline: dict, tolerance: float, absolute: bool) -> list:
    failures = []

    cont = current["continuous_tokens_per_s"]
    static = current["static_tokens_per_s"]
    # 5% grace: the invariant is "continuous does not lose", but a
    # zero-tolerance tie-break on shared CI runners is a flake source.
    if cont < static * 0.95:
        failures.append(
            f"continuous batching LOSES to the static server: "
            f"{cont:.1f} < {static:.1f} tokens/s (speedup {cont / static:.2f}x)"
        )

    if absolute:
        base, cur, what = baseline["continuous_tokens_per_s"], cont, "continuous tokens/s"
    else:
        base, cur, what = baseline["speedup"], current["speedup"], "continuous/static speedup"
    if cur < base * (1.0 - tolerance):
        failures.append(
            f"{what} regressed >{tolerance:.0%} vs baseline: "
            f"{cur:.3f} < {base:.3f} * {1 - tolerance:.2f}"
        )

    # 3. shared-prefix workload: the paged pool (prefix sharing on) must
    #    not lose to the contiguous engine on the long-prompt workload it
    #    exists to win (same 5% tie-break grace), and its speedup ratio
    #    must hold its trajectory vs the baseline.
    sp = current.get("shared_prefix")
    if sp is not None:
        if sp["paged_tokens_per_s"] < sp["contiguous_tokens_per_s"] * 0.95:
            failures.append(
                f"paged+prefix-sharing LOSES to contiguous on the "
                f"shared-prefix workload: {sp['paged_tokens_per_s']:.1f} < "
                f"{sp['contiguous_tokens_per_s']:.1f} tokens/s "
                f"(speedup {sp['paged_speedup']:.2f}x)"
            )
        if sp["prefix_hits"] == 0:
            failures.append(
                "prefix cache recorded ZERO hits on the shared-prefix "
                "workload — sharing is not engaging"
            )
        base_sp = baseline.get("shared_prefix")
        if base_sp is not None and sp["paged_speedup"] < \
                base_sp["paged_speedup"] * (1.0 - tolerance):
            failures.append(
                f"paged/contiguous shared-prefix speedup regressed "
                f">{tolerance:.0%} vs baseline: {sp['paged_speedup']:.3f} < "
                f"{base_sp['paged_speedup']:.3f} * {1 - tolerance:.2f}"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw tokens/s instead of the speedup ratio")
    args = ap.parse_args(argv)

    current = json.loads(Path(args.current).read_text())
    baseline = json.loads(Path(args.baseline).read_text())

    if current.get("workload") != baseline.get("workload"):
        print("NOTE: workload changed since baseline was recorded — "
              "trajectory comparison is apples-to-oranges; refresh the baseline.",
              file=sys.stderr)

    failures = check(current, baseline, args.tolerance, args.absolute)
    print(
        f"serving perf: static={current['static_tokens_per_s']:.1f} tok/s, "
        f"continuous={current['continuous_tokens_per_s']:.1f} tok/s "
        f"(speedup {current['speedup']:.2f}x; baseline {baseline['speedup']:.2f}x)"
    )
    sp = current.get("shared_prefix")
    if sp is not None:
        mem = sp["memory"]
        print(
            f"shared-prefix: contiguous={sp['contiguous_tokens_per_s']:.1f} "
            f"tok/s, paged={sp['paged_tokens_per_s']:.1f} tok/s "
            f"(speedup {sp['paged_speedup']:.2f}x, hits {sp['prefix_hits']}, "
            f"pages {mem['high_water_pages']}/{mem['contiguous_pages_equiv']} "
            f"= {mem['capacity_ratio']:.2f} of contiguous)"
        )
    for f in failures:
        print(f"SERVING PERF FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
