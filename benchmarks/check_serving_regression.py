"""CI perf-trajectory gate for the serving engine.

Compares a fresh ``BENCH_serving.json`` (written by
``benchmarks/run.py --json``) against the checked-in baseline and
FAILS (exit 1) when a serving-perf invariant breaks.  Every invariant
is printed as a PASS/FAIL table row (shared plumbing: ``_gate.py``):

1. **relative**: continuous-batching tokens/s must not LOSE to the
   static lock-step server on the mixed-length workload (with a 5%
   tie-break grace for shared-runner noise) — this is the
   machine-independent relation the scheduler exists to win, so it
   gates unconditionally;
2. **trajectory**: continuous-batching tokens/s must not regress more
   than ``--tolerance`` (default 20%) against the checked-in baseline.
   Absolute tokens/s are host-dependent, so the trajectory check
   compares the continuous/static SPEEDUP ratio by default (stable
   across runner generations); pass ``--absolute`` to compare raw
   tokens/s against a baseline recorded on identical hardware;
3. **shared-prefix**: on the long-prompt shared-prefix workload the
   paged pool (prefix sharing on) must not lose to the contiguous
   engine, the prefix cache must record hits, and the paged/contiguous
   speedup ratio must hold its trajectory vs the baseline.

Refreshing the baseline after an intentional change: copy the CI
artifact (or a local ``--json`` run's output) over
``benchmarks/baselines/BENCH_serving.json`` and commit it.

Usage:
    python benchmarks/check_serving_regression.py \
        --current BENCH_serving.json \
        [--baseline benchmarks/baselines/BENCH_serving.json] \
        [--tolerance 0.2] [--absolute]
"""

from __future__ import annotations

from pathlib import Path
from typing import List

from _gate import GateRow, emit, load_current_and_baseline, make_parser

DEFAULT_BASELINE = Path(__file__).parent / "baselines" / "BENCH_serving.json"


def check(current: dict, baseline: dict, tolerance: float,
          absolute: bool) -> List[GateRow]:
    rows = []

    cont = current["continuous_tokens_per_s"]
    static = current["static_tokens_per_s"]
    # 5% grace: the invariant is "continuous does not lose", but a
    # zero-tolerance tie-break on shared CI runners is a flake source.
    rows.append(GateRow(
        key="continuous_vs_static",
        passed=cont >= static * 0.95,
        value=f"{cont / static:.2f}x",
        bound=">= 0.95x static",
        detail=f"continuous batching LOSES to the static server: "
               f"{cont:.1f} < {static:.1f} tokens/s (speedup {cont / static:.2f}x)",
    ))

    if absolute:
        base, cur, what = baseline["continuous_tokens_per_s"], cont, "continuous tokens/s"
    else:
        base, cur, what = baseline["speedup"], current["speedup"], "continuous/static speedup"
    rows.append(GateRow(
        key="trajectory" + ("_absolute" if absolute else ""),
        passed=cur >= base * (1.0 - tolerance),
        value=f"{cur:.3f}",
        bound=f">= {base:.3f} * {1 - tolerance:.2f}",
        detail=f"{what} regressed >{tolerance:.0%} vs baseline: "
               f"{cur:.3f} < {base:.3f} * {1 - tolerance:.2f}",
    ))

    # 3. shared-prefix workload: the paged pool (prefix sharing on) must
    #    not lose to the contiguous engine on the long-prompt workload it
    #    exists to win (same 5% tie-break grace), and its speedup ratio
    #    must hold its trajectory vs the baseline.
    sp = current.get("shared_prefix")
    if sp is not None:
        rows.append(GateRow(
            key="shared_prefix_paged_vs_contiguous",
            passed=sp["paged_tokens_per_s"] >= sp["contiguous_tokens_per_s"] * 0.95,
            value=f"{sp['paged_speedup']:.2f}x",
            bound=">= 0.95x contiguous",
            detail=f"paged+prefix-sharing LOSES to contiguous on the "
                   f"shared-prefix workload: {sp['paged_tokens_per_s']:.1f} < "
                   f"{sp['contiguous_tokens_per_s']:.1f} tokens/s "
                   f"(speedup {sp['paged_speedup']:.2f}x)",
        ))
        rows.append(GateRow(
            key="shared_prefix_hits",
            passed=sp["prefix_hits"] > 0,
            value=str(sp["prefix_hits"]),
            bound="> 0",
            detail="prefix cache recorded ZERO hits on the shared-prefix "
                   "workload — sharing is not engaging",
        ))
        base_sp = baseline.get("shared_prefix")
        if base_sp is not None:
            rows.append(GateRow(
                key="shared_prefix_trajectory",
                passed=sp["paged_speedup"] >= base_sp["paged_speedup"] * (1.0 - tolerance),
                value=f"{sp['paged_speedup']:.3f}",
                bound=f">= {base_sp['paged_speedup']:.3f} * {1 - tolerance:.2f}",
                detail=f"paged/contiguous shared-prefix speedup regressed "
                       f">{tolerance:.0%} vs baseline: {sp['paged_speedup']:.3f} < "
                       f"{base_sp['paged_speedup']:.3f} * {1 - tolerance:.2f}",
            ))
    return rows


def main(argv=None) -> int:
    args = make_parser(DEFAULT_BASELINE).parse_args(argv)
    current, baseline = load_current_and_baseline(args)

    title = (
        f"serving perf: static={current['static_tokens_per_s']:.1f} tok/s, "
        f"continuous={current['continuous_tokens_per_s']:.1f} tok/s "
        f"(speedup {current['speedup']:.2f}x; baseline {baseline['speedup']:.2f}x)"
    )
    sp = current.get("shared_prefix")
    if sp is not None:
        mem = sp["memory"]
        title += (
            f"\nshared-prefix: contiguous={sp['contiguous_tokens_per_s']:.1f} "
            f"tok/s, paged={sp['paged_tokens_per_s']:.1f} tok/s "
            f"(speedup {sp['paged_speedup']:.2f}x, hits {sp['prefix_hits']}, "
            f"pages {mem['high_water_pages']}/{mem['contiguous_pages_equiv']} "
            f"= {mem['capacity_ratio']:.2f} of contiguous)"
        )
    rows = check(current, baseline, args.tolerance, args.absolute)
    return emit(title, rows, "SERVING PERF FAIL")


if __name__ == "__main__":
    raise SystemExit(main())
