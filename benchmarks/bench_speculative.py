"""Speculative-decoding throughput benchmark: ladder-speculative
greedy decode (draft at a cheap rung, verify at f32) vs vanilla f32
greedy decode on the same batch of prompts.

Both sides emit the SAME tokens (the exactness contract — asserted
here as a self-check), so the comparison isolates the speculation win:
vanilla pays one jit dispatch + one host token-sync per token; the
speculative decoder pays ONE draft dispatch (the k-step scan) plus ONE
batched (k+1)-wide f32 verify dispatch per round, and commits 1..k+1
verified tokens per round depending on the measured acceptance rate.
On this toolchain the cheap rung is NOT cheaper per-FLOP (emulated
int8 matmul runs ~2x slower than f32 — see ROADMAP), so the measured
win is dispatch/host-sync amortization: ~2 dispatches and 2 syncs per
~2.4 committed tokens vs 1 dispatch + 1 sync per token.  That is the
same amortization a real deployment banks, just without the
cheap-rung FLOP discount on top.

``speculative_json()`` is the ``BENCH_speculative.json`` payload
recorded per PR (benchmarks/run.py --json);
benchmarks/check_speculative_regression.py gates CI on it against the
checked-in baseline (speculative must not lose to vanilla f32, and the
speedup ratio must not regress).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

#: prompt lengths of the decode batch (mixed, like real traffic); every
#: lane decodes the full budget — tokens/s compares equal token counts.
PROMPT_LENS = (8, 5, 11, 6)
MAX_NEW = 32
MAX_LEN = 64
K = 3
#: q16_16 (the standard FAST path): the coarser q8_8 activation snap
#: flips more near-tied argmaxes on the random-init smoke model (the
#: q8_8 rung is exercised by the exactness suite); q16_16 acceptance
#: ~0.79 is what pays for the verify pass.
DRAFT_LEVEL = "q16_16"


def _build(cfg_name: str = "deepseek_7b"):
    """deepseek_7b smoke: dense GQA (no sliding window), so the f32
    verify segment is ONE fully batched attention call — the families
    whose segment path loops per position inside the graph (gemma2's
    interleaved SWA) pay a verify graph big enough to eat the
    speculation win on this host.  Smoke scale on purpose: per-token
    dispatch/host-sync amortization IS the win being measured (the
    int8 draft rung is emulated and not FLOP-cheaper here)."""
    from repro.configs import smoke
    from repro.models import init_params

    cfg = smoke(cfg_name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(vocab: int):
    rng = np.random.default_rng(7)
    return [rng.integers(1, vocab, size=n).tolist() for n in PROMPT_LENS]


def _vanilla_runner(cfg, params, prompts):
    """Batched vanilla f32 greedy decode: one exact-mode decode step
    per token, all lanes in lock-step (every lane has the same budget,
    so there is no scheduling slack for speculation to hide behind)."""
    from repro.core.precision import MathEngine
    from repro.models import decode_step, init_caches, prefill_step, write_cache_slot
    from repro.models.layers import attach_quantized_weights
    from repro.runtime.speculative import SPEC_CACHE_DTYPE

    engine = MathEngine("f32")
    params = attach_quantized_weights(params, engine.weight_cache, level="q16_16")
    pre = jax.jit(lambda pr, t, c: prefill_step(pr, t, c, cfg, mode="exact"))
    dec = jax.jit(lambda pr, t, p, c: decode_step(pr, t, p, c, cfg, mode="exact"))
    write = jax.jit(write_cache_slot)
    B = len(prompts)

    def run():
        caches = init_caches(cfg, B, MAX_LEN, dtype=SPEC_CACHE_DTYPE)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            single = init_caches(cfg, 1, MAX_LEN, dtype=SPEC_CACHE_DTYPE)
            logits, single = pre(params, jnp.asarray([list(p)], jnp.int32), single)
            caches = write(caches, single, jnp.int32(i))
            tok[i] = int(jnp.argmax(logits, axis=-1)[0])
            pos[i] = len(p)
        out = [[int(t)] for t in tok]
        tok_d, pos_d = jnp.asarray(tok), jnp.asarray(pos)
        for _ in range(MAX_NEW - 1):
            logits, caches = dec(params, tok_d[:, None], pos_d, caches)
            tok_d = jnp.argmax(logits, axis=-1).reshape(-1).astype(jnp.int32)
            pos_d = pos_d + 1
            for i, t in enumerate(np.asarray(tok_d)):
                out[i].append(int(t))
        return out

    return run


def _speculative_runner(cfg, params, prompts):
    from repro.runtime.speculative import LadderSpeculativeDecoder, SpeculativeConfig

    dec = LadderSpeculativeDecoder(
        cfg, params,
        SpeculativeConfig(k=K, draft_level=DRAFT_LEVEL, max_len=MAX_LEN),
    )

    def run():
        return dec.generate(prompts, max_new=MAX_NEW)

    return run, dec


def speculative_json(repeats: int = 5) -> dict:
    cfg, params = _build()
    prompts = _prompts(cfg.vocab)
    run_v = _vanilla_runner(cfg, params, prompts)
    run_s, dec = _speculative_runner(cfg, params, prompts)

    # warm (pays every compile) + the exactness self-check: a benchmark
    # comparing different token streams would be comparing nothing
    vanilla_out = run_v()
    spec_out = run_s()
    assert spec_out == vanilla_out, "speculative decode diverged from vanilla f32"

    # interleaved timed passes (same rationale as bench_serving: shared-
    # host noise lands on both sides of the gated ratio)
    v_walls, s_walls = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_v()
        v_walls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_s()
        s_walls.append(time.perf_counter() - t0)
    v_wall = sorted(v_walls)[len(v_walls) // 2]
    s_wall = sorted(s_walls)[len(s_walls) // 2]
    n_tokens = sum(len(o) for o in spec_out)
    vanilla_tps = n_tokens / v_wall
    spec_tps = n_tokens / s_wall
    return {
        "bench": "speculative",
        "model": "deepseek_7b-smoke",
        "draft_level": DRAFT_LEVEL,
        "k": K,
        "workload": {"prompt_lens": list(PROMPT_LENS), "max_new": MAX_NEW,
                     "max_len": MAX_LEN},
        "tokens": n_tokens,
        "exact": True,
        "acceptance_rate": dec.acceptance_rate,
        "rounds": dec.stats["rounds"],
        "vanilla_f32_tokens_per_s": vanilla_tps,
        "speculative_tokens_per_s": spec_tps,
        "speedup": spec_tps / vanilla_tps,
        # registry tier is always on: the standalone decoder's weight
        # cache counts quantizations/hits even without a server around it
        "telemetry": dec.engine.weight_cache.registry.snapshot(),
    }


def bench_speculative():
    """CSV rows for benchmarks/run.py."""
    p = speculative_json()
    return [
        ("speculative.vanilla_f32_tok_s", 0.0,
         f"tokens_per_s={p['vanilla_f32_tokens_per_s']:.1f},tokens={p['tokens']}"),
        ("speculative.spec_tok_s", 0.0,
         f"tokens_per_s={p['speculative_tokens_per_s']:.1f},"
         f"speedup_vs_vanilla={p['speedup']:.2f},"
         f"acceptance={p['acceptance_rate']:.3f},k={p['k']},"
         f"draft={p['draft_level']}"),
    ]


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
    print(json.dumps(speculative_json(), indent=2))
