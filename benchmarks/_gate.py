"""Shared plumbing for the CI perf-regression gates.

``check_serving_regression.py`` and ``check_speculative_regression.py``
grew the same baseline-loading / arg-parsing / reporting code
independently; this module is the one copy.  A gate script builds a
list of :class:`GateRow` (one per checked invariant) and hands it to
:func:`emit`, which prints a structured per-key PASS/FAIL table — every
invariant visible on every run, not just the ones that failed — and
mirrors the failures to stderr with the gate's prefix so CI logs stay
greppable.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import List, Tuple


@dataclasses.dataclass
class GateRow:
    """One checked invariant: ``key`` names it, ``value`` / ``bound``
    show the measured number against its threshold, ``detail`` is the
    long-form failure explanation (stderr only, and only on FAIL)."""

    key: str
    passed: bool
    value: str
    bound: str
    detail: str = ""


def make_parser(default_baseline: Path) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", default=str(default_baseline))
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw tokens/s instead of the speedup ratio")
    return ap


def load_current_and_baseline(args) -> Tuple[dict, dict]:
    """Read both payloads; warn (stderr) when the recorded workloads
    diverge — the trajectory comparison is then apples-to-oranges and
    the baseline should be refreshed."""
    current = json.loads(Path(args.current).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    if current.get("workload") != baseline.get("workload"):
        print("NOTE: workload changed since baseline was recorded — "
              "trajectory comparison is apples-to-oranges; refresh the baseline.",
              file=sys.stderr)
    return current, baseline


def emit(title: str, rows: List[GateRow], fail_prefix: str) -> int:
    """Print the PASS/FAIL table, mirror failures to stderr, return the
    exit code (0 = all rows passed)."""
    key_w = max([len(r.key) for r in rows] + [len("check")])
    val_w = max([len(r.value) for r in rows] + [len("value")])
    print(title)
    print(f"  {'check':<{key_w}}  {'':6}  {'value':>{val_w}}  bound")
    for r in rows:
        verdict = "PASS" if r.passed else "FAIL"
        print(f"  {r.key:<{key_w}}  [{verdict}]  {r.value:>{val_w}}  {r.bound}")
    failures = [r for r in rows if not r.passed]
    for r in failures:
        print(f"{fail_prefix}: {r.detail or r.key}", file=sys.stderr)
    return 1 if failures else 0
