"""Roofline aggregation: read the dry-run JSON cells and emit the
EXPERIMENTS.md tables (one row per arch x shape x mesh)."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh="single", mode="precise", tag=None):
    cells = {}
    suffix = f"-{tag}" if tag else ""
    for p in sorted(RESULTS.glob(f"*-{mesh}-{mode}{suffix}.json")):
        rec = json.loads(p.read_text())
        if tag is None and any(
            p.name.endswith(f"-{t}.json") for t in ("fsdp", "nosp", "int8")
        ):
            continue
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def roofline_table(mesh="single", mode="precise", tag=None) -> str:
    cells = load_cells(mesh, mode, tag)
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPs | useful ratio | roofline frac | HBM GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        if rec["status"] == "skip":
            rows.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | — | — |")
            continue
        r = rec["roofline"]
        mem = rec["memory"]
        hbm = (mem.get("temp_size_in_bytes") or 0) + (mem.get("argument_size_in_bytes") or 0)
        rows.append(
            f"| {arch} | {shape} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant'].replace('_s','')} | "
            f"{r['model_flops']:.2e} | {r['useful_flop_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {fmt_bytes(hbm)} |"
        )
    return hdr + "\n".join(rows)


def dryrun_table(mesh="single", mode="precise") -> str:
    cells = load_cells(mesh, mode)
    hdr = (
        "| arch | shape | status | compile s | args GiB/dev | temp GiB/dev | "
        "collective bytes/dev | collective ops |\n|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        if rec["status"] == "skip":
            reason = rec["reason"].split("—")[-1].strip()[:60]
            rows.append(f"| {arch} | {shape} | SKIP ({reason}) | — | — | — | — | — |")
            continue
        mem = rec["memory"]
        h = rec["hlo_costs"]
        rows.append(
            f"| {arch} | {shape} | ok | {rec['compile_s']} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{h['total_collective_bytes']:.2e} | {h['total_collective_count']:.0f} |"
        )
    return hdr + "\n".join(rows)


def run():
    rows = []
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        ok = sum(1 for c in cells.values() if c["status"] == "ok")
        skip = sum(1 for c in cells.values() if c["status"] == "skip")
        rows.append((f"roofline.cells_{mesh}", 0.0, f"ok={ok},skip={skip},total={len(cells)}"))
    return rows


if __name__ == "__main__":
    print("## Dry-run (single pod)\n")
    print(dryrun_table("single"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table("single"))
