"""CI gate: the telemetry profiler tier must stay cheap.

Runs the bench_serving mixed workload through TWO continuous servers —
telemetry fully enabled (profiler + tracer) vs fully disabled — with
interleaved timed passes, and FAILS (exit 1) when the enabled side's
median tokens/s drops more than ``--tolerance`` (default 5%) below the
disabled side.  This is the enforcement half of the overhead contract
in docs/observability.md: the registry tier is always on (plain dict
increments, same cost as the ad-hoc counters it replaced), and the
span/timer tier must cost < 5% even when fully on.

The gate also asserts the two servers emit IDENTICAL token streams —
telemetry that changes tokens is a correctness bug, not an overhead
bug (tests/test_telemetry.py pins the same invariant at smoke scale).

Usage:
    PYTHONPATH=src python benchmarks/check_telemetry_overhead.py \
        [--repeats 5] [--tolerance 0.05]
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent))

from _gate import GateRow, emit  # noqa: E402
from bench_serving import MAX_LEN, N_SLOTS, SERVE_LEVEL, _build, _requests  # noqa: E402


def _runner(cfg, params, enabled: bool):
    from repro.runtime.config import ServingConfig
    from repro.runtime.serve import ContinuousBatchingServer
    from repro.runtime.telemetry import TelemetryConfig

    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=N_SLOTS, max_len=MAX_LEN,
                      default_level=SERVE_LEVEL,
                      telemetry=TelemetryConfig(enabled=enabled, trace=enabled)),
    )

    def run():
        fins = srv.serve(_requests(srv))
        toks = sum(f.n_generated for f in fins.values())
        streams = [f.tokens for f in sorted(fins.values(), key=lambda f: f.rid)]
        return toks, streams

    return run


def measure(repeats: int = 5):
    cfg, params = _build()
    run_off = _runner(cfg, params, enabled=False)
    run_on = _runner(cfg, params, enabled=True)
    _, off_streams = run_off()
    _, on_streams = run_on()  # warm: pays every compile on both servers
    identical = True

    off_walls, on_walls = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        off_toks, s_off = run_off()
        off_walls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        on_toks, s_on = run_on()
        on_walls.append(time.perf_counter() - t0)
        identical = identical and (s_off == s_on)
    off_wall = sorted(off_walls)[len(off_walls) // 2]
    on_wall = sorted(on_walls)[len(on_walls) // 2]
    return {
        "off_tokens_per_s": off_toks / off_wall,
        "on_tokens_per_s": on_toks / on_wall,
        "identical_tokens": identical and (off_streams == on_streams),
    }


def check(m: dict, tolerance: float):
    on, off = m["on_tokens_per_s"], m["off_tokens_per_s"]
    return [
        GateRow(
            key="telemetry_overhead",
            passed=on >= off * (1.0 - tolerance),
            value=f"{on / off:.3f}x",
            bound=f">= {1.0 - tolerance:.2f}x disabled",
            detail=f"profiler tier costs more than {tolerance:.0%}: "
                   f"{on:.1f} (on) vs {off:.1f} (off) tokens/s "
                   f"= {1.0 - on / off:.1%} overhead",
        ),
        GateRow(
            key="identical_tokens",
            passed=bool(m["identical_tokens"]),
            value=str(m["identical_tokens"]),
            bound="True",
            detail="telemetry on/off produced DIFFERENT token streams — "
                   "instrumentation is perturbing decode",
        ),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--tolerance", type=float, default=0.05)
    args = ap.parse_args(argv)

    m = measure(args.repeats)
    title = (
        f"telemetry overhead: disabled={m['off_tokens_per_s']:.1f} tok/s, "
        f"enabled={m['on_tokens_per_s']:.1f} tok/s "
        f"({m['on_tokens_per_s'] / m['off_tokens_per_s']:.3f}x)"
    )
    return emit(title, check(m, args.tolerance), "TELEMETRY OVERHEAD FAIL")


if __name__ == "__main__":
    raise SystemExit(main())
