"""Benchmarks mirroring the paper's Table 1 + §6 experiments, CPU-host
edition.  Wall-clock numbers are CPU proxies (the target is TPU v5e and
cycle-exact MCU numbers do not transfer); the *relationships* the paper
claims — error bounds, determinism, crossover structure, O(1) switch —
are what each benchmark checks and reports.

Emits ``name,us_per_call,derived`` CSV rows like every other bench.
"""

from __future__ import annotations

import math
import time

import numpy as np
import jax
import jax.numpy as jnp


def _bench(fn, *args, warmup=3, iters=20, repeats=5):
    """median of `repeats` timing blocks — single-core wall clock on a
    shared host is noisy; medians keep the paper-table relations stable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / iters * 1e6)
    return sorted(times)[len(times) // 2]


def bench_trig():
    """Paper Table 1 rows sin/cos: CORDIC vs libm, plus max abs error
    and bit-determinism (the TPU analogue of Determinism Score)."""
    from repro.core.cordic import cordic_sincos, cordic_sincos_q16
    from repro.core.qformat import Q16_16, to_fixed

    theta = np.linspace(-math.pi, math.pi, 65536).astype(np.float32)
    t_fast = _bench(lambda x: cordic_sincos(x)[0], theta)
    t_std = _bench(lambda x: jnp.sin(x), theta)
    s, _ = cordic_sincos(theta)
    err = float(np.max(np.abs(np.asarray(s) - np.sin(theta))))

    tq = to_fixed(theta, Q16_16)
    s1, c1 = cordic_sincos_q16(tq)
    s2, c2 = cordic_sincos_q16(tq)
    det = float(np.mean(np.asarray(s1) == np.asarray(s2)))
    rows = [
        ("trig.cordic_sin_64k", t_fast, f"max_err={err:.2e}"),
        ("trig.libm_sin_64k", t_std, f"speed_ratio={t_std / t_fast:.3f}"),
        ("trig.determinism", 0.0, f"bitwise_det={det:.4f} (paper: 0.994 timing-det)"),
    ]
    return rows


def bench_universal_family(n: int = 65536):
    """Beyond the paper's Table 1: the universal-CORDIC transcendental
    family (Walther modes) vs the jnp float path — wall clock plus the
    documented error-bound check for each op (core/cordic.py docstring)."""
    from repro.core import cordic as cd
    from repro.core.qformat import Q16_16, to_fixed

    rng = np.random.default_rng(42)
    rows = []

    y = rng.uniform(-100, 100, n).astype(np.float32)
    x = rng.uniform(-100, 100, n).astype(np.float32)
    yq, xq = to_fixed(y, Q16_16), to_fixed(x, Q16_16)
    t_q = _bench(lambda a, b: cd.atan2_q16(a, b), yq, xq)
    t_f = _bench(lambda a, b: jnp.arctan2(a, b), jnp.asarray(y), jnp.asarray(x))
    err = float(np.max(np.abs(
        np.asarray(cd.atan2_q16(yq, xq), np.int64) / 65536.0
        - np.arctan2(np.asarray(yq, np.int64) / 65536.0, np.asarray(xq, np.int64) / 65536.0)
    )))
    rows.append((f"univ.atan2_{n//1024}k", t_q, f"jnp_us={t_f:.1f},max_err={err:.2e} (bound 1e-4)"))

    # linear-vectoring division (ROADMAP div_q16): normalized error vs
    # the documented 2^-15 * (1 + |q|) bound
    den = np.where(np.abs(x) < 1e-3, np.float32(1.0), x)
    yq2, dq = to_fixed(y, Q16_16), to_fixed(den, Q16_16)
    t_q = _bench(lambda a, b: cd.div_q16(a, b), yq2, dq)
    t_f = _bench(lambda a, b: a / b, jnp.asarray(y), jnp.asarray(den))
    got = np.asarray(cd.div_q16(yq2, dq), np.int64) / 65536.0
    want = (np.asarray(yq2, np.int64) / 65536.0) / (np.asarray(dq, np.int64) / 65536.0)
    ok = np.abs(want) < 32767
    err = float(np.max(np.abs(got - want)[ok] / (2.0 ** -15 * (1.0 + np.abs(want[ok])))))
    rows.append((f"univ.div_{n//1024}k", t_q,
                 f"jnp_us={t_f:.1f},err_vs_bound={err:.2f} (must be <= 1)"))

    # (op, fast, precise, inputs, relative?, documented bound) — sqrt and
    # exp have RELATIVE bounds, so their reported error is normalized by
    # the oracle; the rest report max absolute error.
    unary = [
        ("sqrt", cd.sqrt_q16, jnp.sqrt, rng.uniform(0.01, 30000.0, n), True, "rel 3e-5"),
        ("exp", cd.exp_q16, jnp.exp, rng.uniform(-10.0, 10.0, n), True, "rel 6e-5"),
        ("log", cd.log_q16, jnp.log, rng.uniform(0.01, 30000.0, n), False, "abs 8e-5"),
        ("tanh", cd.tanh_q16, jnp.tanh, rng.uniform(-8.0, 8.0, n), False, "abs 6e-5"),
        ("sigmoid", cd.sigmoid_q16, jax.nn.sigmoid, rng.uniform(-8.0, 8.0, n), False, "abs 5e-5"),
    ]
    for name, q_fn, f_fn, vals, relative, bound in unary:
        vals = vals.astype(np.float32)
        vq = to_fixed(vals, Q16_16)
        t_q = _bench(q_fn, vq)
        t_f = _bench(f_fn, jnp.asarray(vals))
        exact = {"sqrt": np.sqrt, "exp": np.exp, "log": np.log, "tanh": np.tanh,
                 "sigmoid": lambda v: 1 / (1 + np.exp(-v))}[name](
            np.asarray(vq, np.int64) / 65536.0)
        err = np.abs(np.asarray(q_fn(vq), np.int64) / 65536.0 - exact)
        if relative:
            # subtract the 1-ulp output-quantization floor before
            # normalizing (the documented bound is 1 ulp + rel * value)
            err = float(np.max(np.maximum(err - 2.0 ** -16, 0.0) / np.abs(exact)))
        else:
            err = float(np.max(err))
        kind = "max_rel_err" if relative else "max_err"
        rows.append((f"univ.{name}_{n//1024}k", t_q, f"jnp_us={t_f:.1f},{kind}={err:.2e} (bound {bound})"))
    return rows


def bench_scalar_mul():
    """Paper Table 1 row mul: Q16.16 vs f32 multiply on vectors, plus
    the Eq. 6 error bound check."""
    from repro.core.qformat import Q16_16, from_fixed, q_mul, to_fixed

    rng = np.random.default_rng(42)
    x = rng.uniform(-100, 100, (1 << 20,)).astype(np.float32)
    y = rng.uniform(-100, 100, (1 << 20,)).astype(np.float32)
    xq, yq = to_fixed(x, Q16_16), to_fixed(y, Q16_16)
    t_q = _bench(lambda a, b: q_mul(a, b), xq, yq)
    t_f = _bench(lambda a, b: a * b, jnp.asarray(x), jnp.asarray(y))
    zq = q_mul(xq, yq)
    err = np.max(
        np.abs(np.asarray(zq, np.int64) / 65536.0
               - (np.asarray(xq, np.int64) / 65536.0) * (np.asarray(yq, np.int64) / 65536.0))
    )
    return [
        ("mul.q16_1M", t_q, f"max_err={err:.3e} (bound 2^-17={2**-17:.3e})"),
        ("mul.f32_1M", t_f, f"note=paper 1.5x is MCU-specific; int8 MXU gives 2x on TPU"),
    ]


def bench_matmul_crossover():
    """Paper §6.4 + §8.1 (the open question): sweep n and find where the
    tiled Q-format kernel crosses naive float.  The paper predicted
    n >= 64 on the MCU and never measured it; we resolve the analogue
    here (CPU host, int8-dot fast path vs f32 matmul)."""
    from repro.models.layers import dot_fast_int8

    rng = np.random.default_rng(42)
    rows = []
    crossover = None
    for n in (4, 8, 16, 32, 64, 128, 256, 512):
        a = rng.uniform(-1, 1, (n, n)).astype(np.float32)
        b = rng.uniform(-1, 1, (n, n)).astype(np.float32)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        t_q = _bench(jax.jit(dot_fast_int8), aj, bj)
        t_f = _bench(jax.jit(jnp.matmul), aj, bj)
        speedup = t_f / t_q
        if crossover is None and speedup >= 1.0 and n >= 32:
            crossover = n
        rows.append((f"matmul.n{n}", t_q, f"float_us={t_f:.1f},speedup={speedup:.2f}"))
    rows.append(
        ("matmul.crossover", 0.0,
         f"first_n_with_speedup>=1: {crossover} (paper predicted n>=64 on LX6, untested)")
    )
    return rows


def bench_switch():
    """Paper Table 1 row switch: two-phase barrier latency, steady state
    (both executables warm), vs the paper's 8.09 us at 240 MHz."""
    from repro.core.precision import MathEngine, Mode

    eng = MathEngine(Mode.PRECISE)
    eng.set_mode(Mode.FAST)
    eng.set_mode(Mode.PRECISE)  # both contexts warm
    lat = []
    for _ in range(50):
        lat.append(eng.set_mode(Mode.FAST))
        lat.append(eng.set_mode(Mode.PRECISE))
    med = sorted(lat)[len(lat) // 2]
    return [
        ("switch.two_phase_barrier", med, f"median_us={med:.2f} (paper: 8.09us @240MHz)"),
        ("switch.count", 0.0, f"n={len(lat)},max_us={max(lat):.1f}"),
    ]


def bench_ladder_switch():
    """Ladder generalization of the switch row: cycling every registered
    level, scoped ``engine.at`` entry/exit, and a per-op policy swap —
    each must stay an O(1) cached-context reference swap."""
    from repro.core.precision import MathEngine, Mode, PrecisionPolicy, ladder_names

    eng = MathEngine(Mode.PRECISE)
    names = ladder_names()
    for nm in names:            # warm every context
        eng.set_level(nm)
    eng.set_level("f32")

    lat = []
    for _ in range(25):
        for nm in names:
            lat.append(eng.set_level(nm))
    lat = [v for v in lat if v > 0.0]
    med = sorted(lat)[len(lat) // 2]

    at_lat = []
    for _ in range(50):
        c0 = eng.switch_stats.total_latency_us
        with eng.at("q8_24"):
            pass
        at_lat.append(eng.switch_stats.total_latency_us - c0)
    at_med = sorted(at_lat)[len(at_lat) // 2]

    pol = PrecisionPolicy(per_op={"sin": "q8_24", "matmul": "f32"})
    eng.set_policy(pol)
    eng.set_policy(None)        # warm both policy contexts
    pol_lat = []
    for _ in range(50):
        pol_lat.append(eng.set_policy(pol))
        pol_lat.append(eng.set_policy(None))
    pol_med = sorted(pol_lat)[len(pol_lat) // 2]

    return [
        ("ladder.cycle_levels", med,
         f"median_us={med:.2f},levels={len(names)} (O(1) per rung)"),
        ("ladder.scoped_at", at_med, f"median_us={at_med:.2f} (enter+exit)"),
        ("ladder.policy_swap", pol_med, f"median_us={pol_med:.2f}"),
    ]


#: (section name, M tokens, config module) — the fused-MLP bench runs at
#: REAL MLP shapes from configs/ (decode-sized M), per the roadmap item.
FUSED_MLP_SHAPES = (
    ("gemma2_2b", 8, "repro.configs.gemma2_2b"),
    ("mixtral_expert", 8, "repro.configs.mixtral_8x22b"),
)


def _fused_mlp_cases(iters: int, repeats: int):
    """Measure fused / unfused / precise SwiGLU medians per config shape."""
    import importlib

    from repro.core.quantization import QuantizedWeightCache
    from repro.models.layers import attach_quantized_weights, swiglu_mlp

    rng = np.random.default_rng(42)
    out = {}
    for name, M, modname in FUSED_MLP_SHAPES:
        cfgmod = importlib.import_module(modname)
        d, f = cfgmod.CONFIG.d_model, cfgmod.CONFIG.d_ff
        params = {
            "norm": jnp.zeros((d,)),
            "w_gate": jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.02,
            "w_up": jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.02,
            "w_down": jnp.asarray(rng.standard_normal((f, d)), jnp.float32) * 0.02,
        }
        x = jnp.asarray(rng.standard_normal((M, d)), jnp.float32)
        qparams = attach_quantized_weights(params, QuantizedWeightCache())
        step = jax.jit(lambda p, x, m: swiglu_mlp(p, x, m), static_argnums=(2,))
        kw = dict(warmup=1, iters=iters, repeats=repeats)
        out[name] = {
            "M": M, "d_model": d, "d_ff": f,
            "unfused_us": _bench(step, params, x, "fast", **kw),
            "fused_us": _bench(step, qparams, x, "fast", **kw),
            "precise_us": _bench(step, params, x, "precise", **kw),
        }
    return out


def _decode_tokens_per_s(max_new: int = 12):
    """Smoke-model decode throughput, FAST (fused + cached weights) vs
    PRECISE — the end-to-end number the fusion and the sampling/host-sync
    satellites move."""
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import init_params
    from repro.models.config import smoke_config
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    out = {}
    for label, level in (("fast", "q16_16"), ("precise", "f32")):
        srv = BatchedServer(
            cfg, params,
            ServerConfig(max_batch=2, max_len=64, max_new=max_new, start_mode=level),
        )
        srv.generate(prompts)  # warm (compile both steps)
        t0 = time.perf_counter()
        outs = srv.generate(prompts)
        dt = time.perf_counter() - t0
        new_toks = sum(len(o) - len(p) for o, p in zip(outs, prompts))
        out[label] = new_toks / dt
    return out


def bench_fused_mlp(iters: int = 2, repeats: int = 3, decode: bool = True):
    """Fused FAST SwiGLU (single deferred correction + quantize-once
    weights) vs the unfused three-dispatch path vs precise, at MLP
    shapes from configs/.  CPU-host proxies: the *relation* that must
    hold (and that the CI smoke gates on) is fused <= unfused — the
    fused path removes two activation quantizations, three per-call
    weight quantizations, and the bf16 HBM round-trip of the gate."""
    cases = _fused_mlp_cases(iters=iters, repeats=repeats)
    rows = []
    for name, c in cases.items():
        rows.append((
            f"fused_mlp.{name}.fused", c["fused_us"],
            f"unfused_us={c['unfused_us']:.0f},precise_us={c['precise_us']:.0f},"
            f"speedup_vs_unfused={c['unfused_us'] / c['fused_us']:.2f},"
            f"M={c['M']},d={c['d_model']},f={c['d_ff']}",
        ))
    if decode:
        tok = _decode_tokens_per_s()
        rows.append((
            "fused_mlp.decode_tok_s", 0.0,
            f"fast={tok['fast']:.1f},precise={tok['precise']:.1f} (smoke model)",
        ))
    return rows


def fused_mlp_json(iters: int = 2, repeats: int = 3) -> dict:
    """The BENCH_fused_mlp.json payload: per-shape medians + decode
    tokens/s, so the perf trajectory records across PRs."""
    return {
        "bench": "fused_mlp",
        "shapes": _fused_mlp_cases(iters=iters, repeats=repeats),
        "decode_tokens_per_s": _decode_tokens_per_s(),
    }


def bench_footprint():
    """Paper §4.3.2: 88-byte static footprint decomposition."""
    from repro.core.qformat import static_footprint_bytes

    fp = static_footprint_bytes()
    return [("footprint.static", 0.0,
             f"dispatch={fp['dispatch_table_bytes']}B,cordic={fp['cordic_table_bytes']}B,"
             f"total={fp['total_bytes']}B (paper: 24+64=88)")]


def bench_deferred_error():
    """Paper Eq. 18: error of deferred-shift vs per-element rounding."""
    from repro.core.linalg import qmatmul_deferred, qmatmul_per_element
    from repro.core.qformat import Q16_16, from_fixed, to_fixed

    rng = np.random.default_rng(42)
    K = 256
    a = to_fixed(rng.uniform(-0.9, 0.9, (32, K)).astype(np.float32), Q16_16)
    b = to_fixed(rng.uniform(-0.9, 0.9, (K, 32)).astype(np.float32), Q16_16)
    want = (np.asarray(a, np.float64) / 65536) @ (np.asarray(b, np.float64) / 65536)
    e_def = np.abs(np.asarray(from_fixed(qmatmul_deferred(a, b, tile_k=K))) - want).mean()
    e_per = np.abs(np.asarray(from_fixed(qmatmul_per_element(a, b, rounding=False))) - want).mean()
    return [("deferred.error_reduction", 0.0,
             f"per_element={e_per:.3e},deferred={e_def:.3e},ratio={e_per / max(e_def, 1e-12):.1f}x")]


ALL = [bench_trig, bench_universal_family, bench_scalar_mul,
       bench_matmul_crossover, bench_switch, bench_ladder_switch,
       bench_fused_mlp, bench_footprint, bench_deferred_error]

#: the CI smoke set: the O(1)-switch claim (binary + ladder), the
#: universal-family error bounds at a reduced batch, and the fused-MLP
#: latency relation (fused <= unfused) — minutes, not hours.
SMOKE = ["switch", "ladder", "universal", "fused_mlp"]

#: generous CPU-host ceiling for the smoke gate: a retrace/rebuild on a
#: switch shows up as milliseconds; shared-runner noise does not.
SMOKE_SWITCH_BUDGET_US = 5e4


def run():
    rows = []
    for fn in ALL:
        rows.extend(fn())
    return rows


def main(argv=None):
    """CLI: ``python benchmarks/bench_paper_tables.py [--smoke] [--out f.csv]``.

    ``--smoke`` runs the switch-latency + ladder + universal-family
    sections only and FAILS (exit 1) if any switch median exceeds the
    O(1) budget — this is the per-PR regression gate in CI, with the
    CSV uploaded as an artifact.
    """
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None, help="write CSV here (default: stdout)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows = []
        rows.extend(bench_switch())
        rows.extend(bench_ladder_switch())
        rows.extend(bench_universal_family(n=8192))
        rows.extend(bench_fused_mlp(iters=1, repeats=3, decode=False))
    else:
        rows = run()

    lines = ["name,us_per_call,derived"]
    lines += [f"{name},{us:.2f},{derived}" for name, us, derived in rows]
    csv = "\n".join(lines) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(csv)
    print(csv, end="")

    if args.smoke:
        switch_rows = [
            (name, us) for name, us, _ in rows
            if name in ("switch.two_phase_barrier", "ladder.cycle_levels",
                        "ladder.scoped_at", "ladder.policy_swap")
        ]
        bad = [(n, u) for n, u in switch_rows if u > SMOKE_SWITCH_BUDGET_US]
        if bad:
            print(f"SMOKE FAIL: switch medians over {SMOKE_SWITCH_BUDGET_US}us: {bad}",
                  file=sys.stderr)
            return 1
        # the fused-MLP perf relation: the single-correction fused path
        # must not lose to the three-dispatch unfused path it replaces.
        slow = []
        for name, us, derived in rows:
            if name.startswith("fused_mlp.") and "unfused_us=" in derived:
                unfused = float(derived.split("unfused_us=")[1].split(",")[0])
                if us > unfused:
                    slow.append((name, us, unfused))
        if slow:
            print(f"SMOKE FAIL: fused SwiGLU median above unfused: {slow}",
                  file=sys.stderr)
            return 1
        print(f"smoke ok: {len(switch_rows)} switch medians under "
              f"{SMOKE_SWITCH_BUDGET_US:.0f}us; fused<=unfused at "
              f"{len(FUSED_MLP_SHAPES)} shapes", file=sys.stderr)
    return 0


if __name__ == "__main__":
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
    raise SystemExit(main())
