"""CI perf-trajectory gate for ladder-speculative decoding.

Compares a fresh ``BENCH_speculative.json`` (written by
``benchmarks/run.py --json``) against the checked-in baseline and
FAILS (exit 1) when either invariant breaks:

1. **relative**: speculative tokens/s must BEAT vanilla f32 greedy
   decode on the smoke workload (with a 5% tie-break grace for
   shared-runner noise).  If drafting + the batched verify cannot
   out-run one-f32-step-per-token, the subsystem is dead weight — this
   is the machine-independent relation that gates unconditionally.
2. **trajectory**: the speculative/vanilla SPEEDUP ratio must not
   regress more than ``--tolerance`` (default 20%) against the
   checked-in baseline (absolute tokens/s are host-dependent; the
   ratio is stable across runner generations).  Pass ``--absolute``
   to compare raw tokens/s against a baseline recorded on identical
   hardware.

Refreshing the baseline after an intentional change: copy the CI
artifact (or a local ``--json`` run's output) over
``benchmarks/baselines/BENCH_speculative.json`` and commit it.

Usage:
    python benchmarks/check_speculative_regression.py \
        --current BENCH_speculative.json \
        [--baseline benchmarks/baselines/BENCH_speculative.json] \
        [--tolerance 0.2] [--absolute]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).parent / "baselines" / "BENCH_speculative.json"


def check(current: dict, baseline: dict, tolerance: float, absolute: bool) -> list:
    failures = []

    spec = current["speculative_tokens_per_s"]
    vanilla = current["vanilla_f32_tokens_per_s"]
    if spec < vanilla * 0.95:
        failures.append(
            f"speculative decode LOSES to vanilla f32: "
            f"{spec:.1f} < {vanilla:.1f} tokens/s (speedup {spec / vanilla:.2f}x, "
            f"acceptance {current.get('acceptance_rate', float('nan')):.3f})"
        )

    if not current.get("exact", False):
        failures.append("benchmark payload does not attest token exactness")

    if absolute:
        base, cur, what = (baseline["speculative_tokens_per_s"], spec,
                           "speculative tokens/s")
    else:
        base, cur, what = baseline["speedup"], current["speedup"], \
            "speculative/vanilla speedup"
    if cur < base * (1.0 - tolerance):
        failures.append(
            f"{what} regressed >{tolerance:.0%} vs baseline: "
            f"{cur:.3f} < {base:.3f} * {1 - tolerance:.2f}"
        )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw tokens/s instead of the speedup ratio")
    args = ap.parse_args(argv)

    current = json.loads(Path(args.current).read_text())
    baseline = json.loads(Path(args.baseline).read_text())

    if current.get("workload") != baseline.get("workload"):
        print("NOTE: workload changed since baseline was recorded — "
              "trajectory comparison is apples-to-oranges; refresh the baseline.",
              file=sys.stderr)

    failures = check(current, baseline, args.tolerance, args.absolute)
    print(
        f"speculative perf: vanilla_f32={current['vanilla_f32_tokens_per_s']:.1f} tok/s, "
        f"speculative={current['speculative_tokens_per_s']:.1f} tok/s "
        f"(speedup {current['speedup']:.2f}x, acceptance "
        f"{current.get('acceptance_rate', float('nan')):.3f}; "
        f"baseline {baseline['speedup']:.2f}x)"
    )
    for f in failures:
        print(f"SPECULATIVE PERF FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
