"""CI perf-trajectory gate for ladder-speculative decoding.

Compares a fresh ``BENCH_speculative.json`` (written by
``benchmarks/run.py --json``) against the checked-in baseline and
FAILS (exit 1) when an invariant breaks.  Every invariant is printed
as a PASS/FAIL table row (shared plumbing: ``_gate.py``):

1. **relative**: speculative tokens/s must BEAT vanilla f32 greedy
   decode on the smoke workload (with a 5% tie-break grace for
   shared-runner noise).  If drafting + the batched verify cannot
   out-run one-f32-step-per-token, the subsystem is dead weight — this
   is the machine-independent relation that gates unconditionally.
2. **exactness**: the payload must attest token exactness (speculative
   output == vanilla f32 greedy, bit-for-bit).
3. **trajectory**: the speculative/vanilla SPEEDUP ratio must not
   regress more than ``--tolerance`` (default 20%) against the
   checked-in baseline (absolute tokens/s are host-dependent; the
   ratio is stable across runner generations).  Pass ``--absolute``
   to compare raw tokens/s against a baseline recorded on identical
   hardware.

Refreshing the baseline after an intentional change: copy the CI
artifact (or a local ``--json`` run's output) over
``benchmarks/baselines/BENCH_speculative.json`` and commit it.

Usage:
    python benchmarks/check_speculative_regression.py \
        --current BENCH_speculative.json \
        [--baseline benchmarks/baselines/BENCH_speculative.json] \
        [--tolerance 0.2] [--absolute]
"""

from __future__ import annotations

from pathlib import Path
from typing import List

from _gate import GateRow, emit, load_current_and_baseline, make_parser

DEFAULT_BASELINE = Path(__file__).parent / "baselines" / "BENCH_speculative.json"


def check(current: dict, baseline: dict, tolerance: float,
          absolute: bool) -> List[GateRow]:
    rows = []

    spec = current["speculative_tokens_per_s"]
    vanilla = current["vanilla_f32_tokens_per_s"]
    rows.append(GateRow(
        key="speculative_vs_vanilla",
        passed=spec >= vanilla * 0.95,
        value=f"{spec / vanilla:.2f}x",
        bound=">= 0.95x vanilla",
        detail=f"speculative decode LOSES to vanilla f32: "
               f"{spec:.1f} < {vanilla:.1f} tokens/s (speedup {spec / vanilla:.2f}x, "
               f"acceptance {current.get('acceptance_rate', float('nan')):.3f})",
    ))

    rows.append(GateRow(
        key="token_exactness",
        passed=bool(current.get("exact", False)),
        value=str(current.get("exact", False)),
        bound="True",
        detail="benchmark payload does not attest token exactness",
    ))

    if absolute:
        base, cur, what = (baseline["speculative_tokens_per_s"], spec,
                           "speculative tokens/s")
    else:
        base, cur, what = baseline["speedup"], current["speedup"], \
            "speculative/vanilla speedup"
    rows.append(GateRow(
        key="trajectory" + ("_absolute" if absolute else ""),
        passed=cur >= base * (1.0 - tolerance),
        value=f"{cur:.3f}",
        bound=f">= {base:.3f} * {1 - tolerance:.2f}",
        detail=f"{what} regressed >{tolerance:.0%} vs baseline: "
               f"{cur:.3f} < {base:.3f} * {1 - tolerance:.2f}",
    ))
    return rows


def main(argv=None) -> int:
    args = make_parser(DEFAULT_BASELINE).parse_args(argv)
    current, baseline = load_current_and_baseline(args)

    title = (
        f"speculative perf: vanilla_f32={current['vanilla_f32_tokens_per_s']:.1f} tok/s, "
        f"speculative={current['speculative_tokens_per_s']:.1f} tok/s "
        f"(speedup {current['speedup']:.2f}x, acceptance "
        f"{current.get('acceptance_rate', float('nan')):.3f}; "
        f"baseline {baseline['speedup']:.2f}x)"
    )
    rows = check(current, baseline, args.tolerance, args.absolute)
    return emit(title, rows, "SPECULATIVE PERF FAIL")


if __name__ == "__main__":
    raise SystemExit(main())
