"""Benchmark harness entrypoint: one section per paper table/figure +
the roofline cell summary.  Prints ``name,us_per_call,derived`` CSV.

Run:  PYTHONPATH=src python -m benchmarks.run [--section trig|universal|mul|matmul|switch|roofline|all]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks import bench_paper_tables, roofline  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()

    sections = {
        "trig": bench_paper_tables.bench_trig,
        "universal": bench_paper_tables.bench_universal_family,
        "mul": bench_paper_tables.bench_scalar_mul,
        "matmul": bench_paper_tables.bench_matmul_crossover,
        "switch": bench_paper_tables.bench_switch,
        "ladder": bench_paper_tables.bench_ladder_switch,
        "footprint": bench_paper_tables.bench_footprint,
        "deferred": bench_paper_tables.bench_deferred_error,
        "roofline": roofline.run,
    }
    todo = sections.values() if args.section == "all" else [sections[args.section]]

    print("name,us_per_call,derived")
    for fn in todo:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
