"""Benchmark harness entrypoint: one section per paper table/figure +
the roofline cell summary.  Prints ``name,us_per_call,derived`` CSV.

Run:  PYTHONPATH=src python -m benchmarks.run [--section trig|universal|mul|matmul|switch|fused_mlp|roofline|all]

``--json`` additionally records the fused-MLP perf trajectory: writes
``BENCH_fused_mlp.json`` (fused/unfused/precise medians at the
configs/ MLP shapes + smoke-model decode tokens/s) next to the CSV
output, so successive PRs accumulate comparable numbers.
"""

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks import bench_paper_tables, roofline  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument(
        "--json", nargs="?", const="BENCH_fused_mlp.json", default=None,
        metavar="PATH",
        help="also write the fused-MLP medians + decode tokens/s as JSON "
             "(default path: BENCH_fused_mlp.json)",
    )
    args = ap.parse_args()

    sections = {
        "trig": bench_paper_tables.bench_trig,
        "universal": bench_paper_tables.bench_universal_family,
        "mul": bench_paper_tables.bench_scalar_mul,
        "matmul": bench_paper_tables.bench_matmul_crossover,
        "switch": bench_paper_tables.bench_switch,
        "ladder": bench_paper_tables.bench_ladder_switch,
        "fused_mlp": bench_paper_tables.bench_fused_mlp,
        "footprint": bench_paper_tables.bench_footprint,
        "deferred": bench_paper_tables.bench_deferred_error,
        "roofline": roofline.run,
    }

    if args.json is not None or args.section == "json-only":
        payload = bench_paper_tables.fused_mlp_json()
        out_path = args.json or "BENCH_fused_mlp.json"
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {out_path}", file=sys.stderr)
        if args.section == "json-only":
            return
        # the JSON payload already ran the fused-MLP suite — don't pay
        # for it twice in the same invocation
        sections.pop("fused_mlp", None)
        if args.section == "fused_mlp":
            return

    todo = sections.values() if args.section == "all" else [sections[args.section]]

    print("name,us_per_call,derived")
    for fn in todo:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
