"""Benchmark harness entrypoint: one section per paper table/figure +
the roofline cell summary.  Prints ``name,us_per_call,derived`` CSV.

Run:  PYTHONPATH=src python -m benchmarks.run [--section trig|universal|mul|matmul|switch|fused_mlp|serving|roofline|all]

``--json`` additionally records the perf trajectories: writes
``BENCH_fused_mlp.json`` (fused/unfused/precise medians at the
configs/ MLP shapes + smoke-model decode tokens/s),
``BENCH_serving.json`` (static vs continuous-batching tokens/s on the
mixed-length serving workload — gated in CI by
benchmarks/check_serving_regression.py) AND
``BENCH_speculative.json`` (ladder-speculative vs vanilla f32 greedy
tokens/s — gated in CI by benchmarks/check_speculative_regression.py)
next to the CSV output, so successive PRs accumulate comparable
numbers.  The serving run also drops ``trace.json`` (Chrome
``trace_event`` profile of the continuous engine — open in Perfetto)
and ``metrics.prom`` (Prometheus text exposition) beside the JSONs;
CI uploads all of them as artifacts (see docs/observability.md).
"""

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks import bench_paper_tables, bench_serving, bench_speculative, roofline  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument(
        "--json", nargs="?", const="BENCH_fused_mlp.json", default=None,
        metavar="PATH",
        help="also write the fused-MLP medians + decode tokens/s as JSON "
             "(default path: BENCH_fused_mlp.json); BENCH_serving.json is "
             "written next to it",
    )
    args = ap.parse_args()

    sections = {
        "trig": bench_paper_tables.bench_trig,
        "universal": bench_paper_tables.bench_universal_family,
        "mul": bench_paper_tables.bench_scalar_mul,
        "matmul": bench_paper_tables.bench_matmul_crossover,
        "switch": bench_paper_tables.bench_switch,
        "ladder": bench_paper_tables.bench_ladder_switch,
        "fused_mlp": bench_paper_tables.bench_fused_mlp,
        "serving": bench_serving.bench_serving,
        "speculative": bench_speculative.bench_speculative,
        "footprint": bench_paper_tables.bench_footprint,
        "deferred": bench_paper_tables.bench_deferred_error,
        "roofline": roofline.run,
    }

    if args.json is not None or args.section == "json-only":
        payload = bench_paper_tables.fused_mlp_json()
        out_path = args.json or "BENCH_fused_mlp.json"
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {out_path}", file=sys.stderr)
        out_dir = Path(out_path).parent
        serving_payload = bench_serving.serving_json(
            trace_out=str(out_dir / "trace.json"),
            metrics_out=str(out_dir / "metrics.prom"),
        )
        print(f"wrote {out_dir / 'trace.json'} and {out_dir / 'metrics.prom'}",
              file=sys.stderr)
        serving_path = Path(out_path).parent / "BENCH_serving.json"
        serving_path.write_text(json.dumps(serving_payload, indent=2) + "\n")
        print(f"wrote {serving_path}", file=sys.stderr)
        spec_payload = bench_speculative.speculative_json()
        spec_path = Path(out_path).parent / "BENCH_speculative.json"
        spec_path.write_text(json.dumps(spec_payload, indent=2) + "\n")
        print(f"wrote {spec_path}", file=sys.stderr)
        if args.section == "json-only":
            return
        # the JSON payloads already ran those suites — don't pay for
        # them twice in the same invocation
        sections.pop("fused_mlp", None)
        sections.pop("serving", None)
        sections.pop("speculative", None)
        if args.section in ("fused_mlp", "serving", "speculative"):
            return

    todo = sections.values() if args.section == "all" else [sections[args.section]]

    print("name,us_per_call,derived")
    for fn in todo:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
