"""Serving-throughput benchmark: static lock-step batching vs the
continuous-batching engine on a MIXED workload (mixed prompt lengths,
mixed per-request decode budgets) — the traffic shape the scheduler
exists for.

Both servers run the same smoke model at the same (FAST) level, so the
comparison isolates the *scheduling* win: the static server decodes
every wave until its longest request finishes (short requests burn
slots as padding), while the continuous engine evicts at each request's
own budget and refills the slot from the queue.

Useful-token accounting: a request contributes at most its own
``max_new`` tokens; anything a server generates beyond that is wasted
work and is NOT counted (this is what penalizes lock-step waves).

``serving_json()`` is the ``BENCH_serving.json`` payload recorded per
PR (benchmarks/run.py --json); benchmarks/check_serving_regression.py
gates CI on it against the checked-in baseline.
"""

from __future__ import annotations

import time

import jax
import numpy as np

#: (prompt_len, max_new) per request — mixed lengths, bimodal budgets
#: (short lookups interleaved with long generations: the traffic shape
#: where lock-step waves burn ~half their lane-steps as padding).
WORKLOAD = (
    (8, 2), (5, 24), (11, 2), (4, 24),
    (7, 2), (9, 24), (6, 2), (10, 24),
)

N_SLOTS = 4
MAX_LEN = 64
SERVE_LEVEL = "q16_16"   # FAST: exercises the quantized-weight cache +
                         # fused SwiGLU decode path under request churn

#: shared-prefix workload: long prompts that all open with the same
#: PREFIX_LEN tokens (system prompt / few-shot header traffic).  The
#: paged pool with prefix sharing prefills the header ONCE and attaches
#: its pages to every later request; the contiguous engine re-runs the
#: full prompt per request (and retraces per distinct length).
PREFIX_LEN = 48
SP_TAILS = ((2, 8), (5, 8), (9, 8), (3, 8), (7, 8), (11, 8),
            (4, 8), (6, 8), (10, 8), (8, 8), (2, 8), (5, 8))
SP_MAX_LEN = 128
SP_PAGE = 16


def _requests(server=None):
    from repro.runtime.scheduler import Request

    rng = np.random.default_rng(7)
    out = []
    for i, (plen, max_new) in enumerate(WORKLOAD):
        rid = server.next_rid() if server is not None else i
        prompt = rng.integers(1, 100, size=plen).tolist()
        out.append(Request(rid=rid, prompt=prompt, max_new=max_new, level=SERVE_LEVEL))
    return out


def _build(cfg_name: str = "gemma2_2b"):
    """Smoke-family config scaled up so a decode step is compute-bound:
    the scheduling comparison must measure device time saved, not
    python dispatch noise (at d_model=64 a step is all dispatch)."""
    import dataclasses

    from repro.configs import smoke
    from repro.models import init_params

    cfg = smoke(cfg_name)
    cfg = dataclasses.replace(cfg, name=cfg.name + "-bench", d_model=256, d_ff=1024)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _static_runner(cfg, params):
    """Workload closure for the static BatchedServer: FIFO waves of
    N_SLOTS, each decoded to the wave's LONGEST budget."""
    from repro.runtime.serve import BatchedServer, ServerConfig

    srv = BatchedServer(
        cfg, params,
        ServerConfig(max_batch=N_SLOTS, max_len=MAX_LEN, max_new=1,
                     start_mode=SERVE_LEVEL),
    )
    reqs = _requests()
    waves = [reqs[i:i + N_SLOTS] for i in range(0, len(reqs), N_SLOTS)]

    def run():
        useful = 0
        for wave in waves:
            srv.scfg.max_new = max(r.max_new for r in wave)  # lock-step cost
            outs = srv.generate([r.prompt for r in wave])
            for r, o in zip(wave, outs):
                useful += min(len(o) - len(r.prompt), r.max_new)
        return useful

    return run, lambda: {}


def _continuous_runner(cfg, params):
    """Workload closure for the continuous engine (one persistent
    server — the pool is allocated once; timed passes reuse the warm
    jit cache exactly like a long-lived serving process would).

    Telemetry runs ENABLED here on purpose: the recorded tokens/s is
    what a production deployment with profiling on would see, the trace
    becomes the CI artifact, and check_telemetry_overhead.py separately
    bounds the on-vs-off delta."""
    from repro.runtime.config import ServingConfig
    from repro.runtime.serve import ContinuousBatchingServer
    from repro.runtime.telemetry import TelemetryConfig

    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=N_SLOTS, max_len=MAX_LEN,
                      default_level=SERVE_LEVEL,
                      telemetry=TelemetryConfig(enabled=True, trace=True)),
    )

    def run():
        fins = srv.serve(_requests(srv))
        return sum(f.n_generated for f in fins.values())

    return run, srv


def _shared_prefix_requests(srv):
    from repro.runtime.scheduler import Request

    rng = np.random.default_rng(11)
    prefix = rng.integers(1, 100, size=PREFIX_LEN).tolist()
    return [
        Request(rid=srv.next_rid(),
                prompt=prefix + rng.integers(1, 100, size=tail).tolist(),
                max_new=max_new, level=SERVE_LEVEL)
        for tail, max_new in SP_TAILS
    ]


def _shared_prefix_runner(cfg, params, paged: bool):
    """Shared-prefix workload through one persistent continuous server
    — contiguous pool, or paged pool with prefix sharing on."""
    from repro.runtime.config import ServingConfig
    from repro.runtime.serve import ContinuousBatchingServer

    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(
            n_slots=N_SLOTS, max_len=SP_MAX_LEN, default_level=SERVE_LEVEL,
            cache="paged" if paged else "contiguous",
            page_size=SP_PAGE, prefix_sharing=paged,
        ),
    )

    def run():
        fins = srv.serve(_shared_prefix_requests(srv))
        return sum(f.n_generated for f in fins.values())

    return run, srv


def shared_prefix_json(repeats: int = 3) -> dict:
    """The ``shared_prefix`` section of the serving payload: paged +
    prefix-sharing vs contiguous on the long-prompt workload, plus the
    page-pool capacity numbers (high-water pages vs the slot-contiguous
    equivalent) that the throughput ratio alone doesn't show."""
    cfg, params = _build("deepseek_7b")  # full-context attn: shareable
    run_c, _ = _shared_prefix_runner(cfg, params, paged=False)
    run_p, srv_p = _shared_prefix_runner(cfg, params, paged=True)
    run_c(); run_p()  # warm: compiles + primes the prefix cache

    c_walls, p_walls = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        c_toks = run_c()
        c_walls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        p_toks = run_p()
        p_walls.append(time.perf_counter() - t0)
    c_wall = sorted(c_walls)[len(c_walls) // 2]
    p_wall = sorted(p_walls)[len(p_walls) // 2]
    cont_tps = c_toks / c_wall
    paged_tps = p_toks / p_wall

    report = srv_p.cache_ops.report()
    full = report["groups"][f"L{SP_MAX_LEN}"]
    return {
        "workload": {"prefix_len": PREFIX_LEN, "tails": list(SP_TAILS),
                     "n_slots": N_SLOTS, "max_len": SP_MAX_LEN,
                     "page_size": SP_PAGE},
        "contiguous_tokens_per_s": cont_tps,
        "paged_tokens_per_s": paged_tps,
        "paged_speedup": paged_tps / cont_tps,
        "prefix_hits": srv_p.stats["prefix_hits"],
        "prefix_tokens_reused": srv_p.stats["prefix_tokens_reused"],
        "prefill_chunks": srv_p.stats["prefill_chunks"],
        "memory": {
            "page_size": SP_PAGE,
            "high_water_pages": full["high_water"],
            "contiguous_pages_equiv": full["contiguous_pages_equiv"],
            "capacity_ratio": full["high_water"] / full["contiguous_pages_equiv"],
        },
    }


def serving_json(repeats: int = 3, trace_out=None, metrics_out=None) -> dict:
    """``trace_out`` / ``metrics_out``: optional paths; when given, the
    continuous server's Chrome trace and Prometheus exposition are
    written there after the timed passes (CI uploads both as
    artifacts)."""
    cfg, params = _build()
    run_s, _ = _static_runner(cfg, params)
    run_c, srv_c = _continuous_runner(cfg, params)
    run_s(); run_c()  # warm: pays every compile on both engines

    # INTERLEAVED timed passes: shared-host noise hits both servers in
    # the same window, so the gated speedup ratio stays stable even
    # when absolute tokens/s swing between invocations.
    s_walls, c_walls = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        s_toks = run_s()
        s_walls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        c_toks = run_c()
        c_walls.append(time.perf_counter() - t0)
    s_wall = sorted(s_walls)[len(s_walls) // 2]
    c_wall = sorted(c_walls)[len(c_walls) // 2]
    stats = dict(srv_c.stats)
    static_tps = s_toks / s_wall
    cont_tps = c_toks / c_wall
    if trace_out is not None:
        srv_c.telemetry.write_trace(trace_out)
    if metrics_out is not None:
        with open(metrics_out, "w") as f:
            f.write(srv_c.render_prometheus())
    return {
        "bench": "serving",
        "model": "gemma2_2b-smoke",
        "level": SERVE_LEVEL,
        "workload": {"requests": list(WORKLOAD), "n_slots": N_SLOTS,
                     "max_len": MAX_LEN},
        "useful_tokens": {"static": s_toks, "continuous": c_toks},
        "static_tokens_per_s": static_tps,
        "continuous_tokens_per_s": cont_tps,
        "speedup": cont_tps / static_tps,
        "continuous_stats": stats,
        "telemetry": srv_c.metrics_snapshot(),
        "shared_prefix": shared_prefix_json(repeats),
    }


def bench_serving():
    """CSV rows for benchmarks/run.py."""
    p = serving_json()
    return [
        ("serving.static_tok_s", 0.0,
         f"tokens_per_s={p['static_tokens_per_s']:.1f},useful={p['useful_tokens']['static']}"),
        ("serving.continuous_tok_s", 0.0,
         f"tokens_per_s={p['continuous_tokens_per_s']:.1f},"
         f"speedup_vs_static={p['speedup']:.2f},"
         f"decode_steps={p['continuous_stats']['decode_steps']}"),
        ("serving.paged_shared_prefix_tok_s", 0.0,
         f"tokens_per_s={p['shared_prefix']['paged_tokens_per_s']:.1f},"
         f"speedup_vs_contiguous={p['shared_prefix']['paged_speedup']:.2f},"
         f"prefix_hits={p['shared_prefix']['prefix_hits']},"
         f"capacity_ratio={p['shared_prefix']['memory']['capacity_ratio']:.2f}"),
    ]


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
    print(json.dumps(serving_json(), indent=2))
