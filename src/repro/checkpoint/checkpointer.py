"""Sharded, atomic, async, topology-independent checkpointing.

* **Atomic**: writes go to ``step_N.tmp/`` and are renamed to
  ``step_N/`` only after fsync — a crash mid-save never corrupts the
  latest checkpoint (restore picks the newest *committed* step).
* **Async**: ``save()`` snapshots device arrays to host (blocking only
  on D2H) and hands serialization to a background thread — the paper's
  Core-0/Core-1 split applied to I/O.
* **Topology-independent**: leaves are stored as full logical arrays
  (np.save per leaf) plus a JSON manifest; ``restore()`` re-shards onto
  whatever mesh the new job runs — elastic scaling (grow/shrink the
  pod) is a restore, not a special case.

For 1000+-node scale the per-leaf files would be chunked per shard
(each host writes its own slice); the manifest format already carries
the pytree structure needed for that — see DESIGN.md §5.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        out.append((name, leaf))
    return out


class Checkpointer:
    #: in-process registry of in-flight async saves, keyed by resolved
    #: directory.  A NEW Checkpointer on the same directory joins any
    #: pending save first, so "restart after crash" never reads a stale
    #: latest_step because the previous instance's background thread had
    #: not committed yet (the resume-cadence bug: restoring step 3 while
    #: step 7's rename was still in flight).  A real process crash kills
    #: the thread mid-tmp-write, which the .tmp atomicity already covers.
    _pending: dict = {}
    _pending_lock = threading.Lock()

    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0
        self.last_save_s = 0.0
        self._join_pending()

    def _key(self) -> str:
        return str(self.dir.resolve())

    def _join_pending(self) -> None:
        # the lock also serializes against save()'s register+start pair,
        # so a fetched thread is always already started (join-able)
        with self._pending_lock:
            thread = Checkpointer._pending.get(self._key())
        if thread is not None and thread is not threading.current_thread():
            thread.join()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot now, serialize in the background (unless blocking)."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H snapshot

        def work():
            t0 = time.time()
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": []}
            for name, leaf in _flatten_with_names(host_tree):
                fname = name.replace("/", "__") + ".npy"
                np.save(tmp / fname, leaf)
                manifest["leaves"].append(
                    {"name": name, "file": fname,
                     "shape": list(np.shape(leaf)), "dtype": str(np.asarray(leaf).dtype)}
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            fd = os.open(tmp, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self.save_count += 1
            self.last_save_s = time.time() - t0
            self._gc()
            # deregister so the class-level registry stays bounded; only
            # our own entry (a newer save may have replaced it)
            with Checkpointer._pending_lock:
                if Checkpointer._pending.get(self._key()) is threading.current_thread():
                    del Checkpointer._pending[self._key()]

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            with self._pending_lock:
                # register and start under one lock: a concurrent
                # _join_pending can never observe an unstarted thread
                Checkpointer._pending[self._key()] = self._thread
                self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        self._join_pending()  # never list around an uncommitted save
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        *,
        shardings: Any = None,
    ) -> Any:
        """Restore into the structure of ``template``.

        ``shardings`` (optional pytree of NamedSharding, e.g. for a NEW
        mesh) re-shards each full logical array via jax.device_put —
        this is the elastic-scaling path.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_name = {leaf["name"]: leaf for leaf in manifest["leaves"]}

        names = [n for n, _ in _flatten_with_names(template)]
        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves_t)
        )
        out = []
        for name, tmpl, sh in zip(names, leaves_t, shard_leaves):
            rec = by_name[name]
            arr = np.load(d / rec["file"])
            assert list(arr.shape) == list(np.shape(tmpl)), (name, arr.shape, np.shape(tmpl))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype if hasattr(tmpl, "dtype") else None))
        return jax.tree_util.tree_unflatten(treedef, out)
