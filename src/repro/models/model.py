"""The language model: embeddings -> scanned periods -> head.

Public step functions (all pure, jit/pjit-ready):

``train_loss``    — causal LM loss with sequence-chunked cross-entropy
                    (never materializes (B, S, V) logits), MoE aux
                    losses, z-loss; remat over periods.
``prefill_step``  — segment forward, returns last-position logits and
                    populated caches.
``decode_step``   — one token against caches.

Modality stubs (phi-3-vision, musicgen): ``extra_embeds`` (B, P, d) are
pre-computed patch/frame embeddings added onto the first P token
positions — the backbone is the assigned architecture; the frontend is
out of scope per the assignment.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec, init_from_specs, rms_norm, softcap
from repro.models.transformer import init_period_cache, period_forward, period_specs

__all__ = [
    "param_specs",
    "init_params",
    "init_caches",
    "cache_layout",
    "train_loss",
    "prefill_step",
    "decode_step",
    "segment_step",
    "commit_segment",
    "reset_cache_slot",
    "write_cache_slot",
    "truncate_cache_slot",
]

Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]
_id: Constrain = lambda x, kind: x


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def _stack_spec(s: Spec, n: int) -> Spec:
    return Spec((n,) + s.shape, ("layer",) + s.axes, s.dtype, s.init, s.scale)


def param_specs(cfg: ModelConfig) -> dict:
    period = period_specs(cfg)
    stacked = jax.tree.map(
        lambda s: _stack_spec(s, cfg.n_periods),
        period,
        is_leaf=lambda x: isinstance(x, Spec),
    )
    out = {
        "embed": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "periods": stacked,
        "final_norm": Spec((cfg.d_model,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return out


# Alias used by config.param_count()
param_shapes = param_specs


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    params = init_from_specs(param_specs(cfg), key)
    return jax.tree.map(lambda x: x.astype(dtype), params)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                quantized: bool = False):
    """Stacked (n_periods, ...) cache pytree.  quantized=True stores
    attention KV in Q-format int8 (+ per-slot exponents)."""
    one = init_period_cache(cfg, batch, max_len, dtype, quantized=quantized)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape).copy(), one
    )


def cache_layout(cfg: ModelConfig, max_len: int):
    """The model's cache-memory layout, one entry per period position:
    ``(key, kind, L)`` where ``key`` is the cache-tree key (``pos{i}``),
    ``kind`` is the layer kind and ``L`` is the POSITION-INDEXED cache
    length (``min(window, max_len)`` for sliding-window attention,
    ``max_len`` for full attention / MLA) — or ``None`` for cumulative
    state (SSM), which is O(1) per slot and position-free.

    This is the single source of truth the paged
    :class:`~repro.runtime.cachepool.PagedCachePool` builds its page
    groups from: position-indexed caches page; cumulative caches stay
    slot-contiguous.
    """
    out = []
    for i, spec in enumerate(cfg.period):
        if spec.kind in ("attn",):
            L = min(spec.window, max_len) if spec.window else max_len
            out.append((f"pos{i}", spec.kind, L))
        elif spec.kind == "mla":
            out.append((f"pos{i}", "mla", max_len))
        else:
            out.append((f"pos{i}", spec.kind, None))
    return out


def reset_cache_slot(caches, cfg: ModelConfig, slot):
    """Reset ONE batch slot of a stacked cache pool (leaves are
    (n_periods, batch, ...)) to its freshly-initialized state.

    Continuous-batching admission hygiene: an evicted request's KV rows,
    position sentinels, SSM state and conv history must never leak into
    the slot's next occupant.  Dispatches to the per-layer resets
    (:func:`~repro.models.attention.reset_attn_cache_slot` etc., vmapped
    over the stacked period axis).  ``slot`` may be traced — jit-safe.
    """
    from repro.models import attention as attn
    from repro.models import ssm as ssm_mod

    reset_fn = {"attn": attn.reset_attn_cache_slot,
                "mla": attn.reset_mla_cache_slot,
                "mamba": ssm_mod.reset_ssm_cache_slot}
    out = {}
    for i, spec in enumerate(cfg.period):
        fn = reset_fn[spec.kind]
        out[f"pos{i}"] = jax.vmap(lambda c, fn=fn: fn(c, slot))(caches[f"pos{i}"])
    return out


def truncate_cache_slot(pool, cfg: ModelConfig, slot, keep_pos, ssm_snapshot=None):
    """Truncate-to-position form of :func:`reset_cache_slot`: roll ONE
    batch slot of a stacked cache pool back so only entries at positions
    ``< keep_pos`` survive.  Position-indexed caches (attn/mla) drop the
    rejected entries in place; SSM caches are cumulative, so their
    rollback needs ``ssm_snapshot`` — a mapping ``pos{i} ->
    {"state", "conv"}`` with leaves ``(n_periods, ...)`` holding the
    slot's cache contents as of ``keep_pos`` (e.g. the per-position
    states from :func:`segment_step`'s ``seg_aux``).  Raises if the
    model has SSM layers and no snapshot is given.  ``slot`` and
    ``keep_pos`` may be traced — jit-safe."""
    from repro.models import attention as attn

    out = {}
    for i, spec in enumerate(cfg.period):
        key = f"pos{i}"
        if spec.kind in ("attn", "mla"):
            out[key] = jax.vmap(
                lambda c: attn.truncate_attn_cache_slot(c, slot, keep_pos)
            )(pool[key])
        else:
            if ssm_snapshot is None or key not in ssm_snapshot:
                raise ValueError(
                    "truncate_cache_slot: SSM caches are cumulative and "
                    f"need an ssm_snapshot entry for {key}"
                )
            snap = ssm_snapshot[key]
            out[key] = {
                k: pool[key][k].at[:, slot].set(snap[k].astype(pool[key][k].dtype))
                for k in pool[key]
            }
    return out


def write_cache_slot(pool, single, slot):
    """Scatter a single-request cache tree (leaves (n_periods, 1, ...))
    into batch slot ``slot`` of a stacked pool — the admission write of
    a freshly prefilled request.  The single cache is fully populated
    from a zero init, so the write itself is also a complete reset of
    the slot.  ``slot`` may be traced — jit-safe."""
    return jax.tree.map(
        lambda p, s: p.at[:, slot].set(s[:, 0].astype(p.dtype)), pool, single
    )


# ---------------------------------------------------------------------------
# backbone
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg: ModelConfig, extra_embeds=None):
    # cast BEFORE the gather: the FSDP all-gather of the table (and the
    # row gather itself) then moves bf16, not the f32 master copy
    x = jnp.take(params["embed"].astype(jnp.bfloat16), tokens, axis=0)
    if extra_embeds is not None and cfg.stub_prefix_len:
        P = cfg.stub_prefix_len
        x = jnp.concatenate(
            [x[:, :P] + extra_embeds.astype(x.dtype), x[:, P:]], axis=1
        )
    return x


def _backbone_train(params, x, cfg: ModelConfig, positions, mode, constrain, remat: bool):
    def body(carry, period_params):
        h, aux = carry
        h2, _, a = period_forward(
            period_params, h, cfg, positions=positions, mode=mode, constrain=constrain
        )
        return (h2, aux + a), None

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((2,), jnp.float32)), params["periods"])
    return x, aux


def _lm_head(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# training loss (chunked CE)
# ---------------------------------------------------------------------------


def _chunked_ce(hidden, head, labels, mask, cfg: ModelConfig, chunk: int = 256,
                mode: str = "precise"):
    """hidden (B,S,d), head (d,V), labels (B,S) -> (sum_loss, sum_zloss, count).

    Scans sequence chunks; the (B, chunk, V) logits are transient.
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    h_c = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    l_c = labels.reshape(B, n, chunk).swapaxes(0, 1)
    m_c = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def step(carry, blk):
        loss_s, z_s, cnt = carry
        h, lab, m = blk
        logits = jnp.dot(
            h.astype(jnp.bfloat16), head.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
        logits = softcap(logits, cfg.final_softcap, mode)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * m
        return (loss_s + ce.sum(), z_s + ((lse * m) ** 2).sum(), cnt + m.sum()), None

    init = (jnp.float32(0), jnp.float32(0), jnp.float32(0))
    (loss_s, z_s, cnt), _ = jax.lax.scan(step, init, (h_c, l_c, m_c))
    return loss_s, z_s, cnt


def train_loss(
    params,
    batch: dict,
    cfg: ModelConfig,
    mode: str = "precise",
    constrain: Constrain = _id,
    remat: bool = True,
    z_coef: float = 1e-4,
):
    """batch: tokens (B,S), labels (B,S), optional loss_mask, extra_embeds.

    Returns (loss, metrics dict).
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x = _embed(params, tokens, cfg, batch.get("extra_embeds"))
    x = constrain(x, "residual")
    x, aux = _backbone_train(params, x, cfg, positions, mode, constrain, remat)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)

    loss_s, z_s, cnt = _chunked_ce(x, _lm_head(params, cfg), labels, mask, cfg, mode=mode)
    ce = loss_s / jnp.maximum(cnt, 1.0)
    z_loss = z_coef * z_s / jnp.maximum(cnt, 1.0)
    loss = ce + z_loss
    metrics = {"ce": ce, "z_loss": z_loss, "tokens": cnt}
    if cfg.moe is not None:
        lb, rz = aux[0] / cfg.n_periods, aux[1] / cfg.n_periods
        loss = loss + cfg.moe.aux_loss_coef * lb + cfg.moe.router_z_coef * rz
        metrics.update({"moe_lb": lb, "moe_z": rz})
    return loss, metrics


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def _scan_with_caches(params, x, caches, cfg, positions, mode, constrain, *,
                      prefill, collect_aux: bool = False):
    """Scan periods with the stacked cache in the CARRY, updated in
    place via dynamic_update_index — ONE cache buffer end to end.

    (Passing caches as scan xs/ys double-buffers them: the stacked ys
    output is distinct from the xs input, costing a full extra cache
    per device — fatal for 32k decode cells.  Measured in EXPERIMENTS.md
    §Perf iteration P2.)

    ``collect_aux=True`` (segment decode): each period's segment
    rollback state rides out as scan ys, stacked to leaves of shape
    ``(n_periods, ...)`` — a third return value.
    """

    def body(carry, xs):
        h, all_caches = carry
        period_params, i = xs
        cache_i = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False), all_caches
        )
        seg_aux = {} if collect_aux else None
        h2, new_cache, _ = period_forward(
            period_params, h, cfg,
            positions=positions, mode=mode, caches=cache_i, prefill=prefill,
            constrain=constrain, seg_aux=seg_aux,
        )
        all_caches = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n.astype(c.dtype), i, 0),
            all_caches, new_cache,
        )
        return (h2, all_caches), seg_aux

    (x, new_caches), aux = jax.lax.scan(
        body, (x, caches),
        (params["periods"], jnp.arange(cfg.n_periods, dtype=jnp.int32)),
    )
    if collect_aux:
        return x, new_caches, aux
    return x, new_caches


def prefill_step(
    params,
    tokens,
    caches,
    cfg: ModelConfig,
    mode: str = "precise",
    constrain: Constrain = _id,
    extra_embeds=None,
):
    """tokens (B,S) from position 0; returns (last_logits (B,V), caches').

    mode="exact" (serving): f32 residual stream and f32 head so the
    prefill and decode derivations of the same prefix agree to f32
    noise — bf16 rounding of an O(1e3) hybrid residual stream costs a
    full ulp (O(10)) per store and broke jamba's greedy consistency.
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed(params, tokens, cfg, extra_embeds)
    if mode == "exact":
        x = x.astype(jnp.float32)
    x, new_caches = _scan_with_caches(params, x, caches, cfg, positions, mode, constrain, prefill=True)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    head_dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    logits = jnp.dot(
        x[:, 0].astype(head_dt),
        _lm_head(params, cfg).astype(head_dt),
        preferred_element_type=jnp.float32,
    )
    return softcap(logits, cfg.final_softcap, mode), new_caches


def decode_step(
    params,
    token,
    position,
    caches,
    cfg: ModelConfig,
    mode: str = "precise",
    constrain: Constrain = _id,
    lane_mask=None,
):
    """token (B,1) at scalar-per-batch ``position`` (B,) -> (logits, caches').

    mode="exact": see :func:`prefill_step` — the serving-consistency
    f32 path.

    ``lane_mask`` (B,) zeroes non-member lanes at the embedding.  The
    continuous-batching server passes its slot mask here: the FAST
    path's PER-TENSOR activation exponents take their amax over the
    whole batch, so without the mask an f32 neighbor's activations
    would perturb a q16_16 request's quantization — masked, a pass's
    input tensor is independent of what the other lanes hold, which is
    what makes a slot's output identical to running it alone.
    """
    B = token.shape[0]
    positions = position.reshape(B, 1).astype(jnp.int32)
    x = _embed(params, token, cfg)
    if mode == "exact":
        x = x.astype(jnp.float32)
    if lane_mask is not None:
        x = x * lane_mask.astype(x.dtype)[:, None, None]
    x, new_caches = _scan_with_caches(params, x, caches, cfg, positions, mode, constrain, prefill=False)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head_dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    logits = jnp.dot(
        x[:, 0].astype(head_dt),
        _lm_head(params, cfg).astype(head_dt),
        preferred_element_type=jnp.float32,
    )
    return softcap(logits, cfg.final_softcap, mode), new_caches


def segment_step(
    params,
    tokens,
    positions,
    caches,
    cfg: ModelConfig,
    mode: str = "exact",
    constrain: Constrain = _id,
    lane_mask=None,
):
    """Mid-sequence segment forward: ``tokens`` (B,S) at explicit
    ``positions`` (B,S) against populated caches — the speculative-
    verify pass.  Returns ``(logits (B,S,V), caches', seg_aux)``.

    All S positions are scored in ONE pass (this is where speculative
    decoding's verification throughput comes from); the caches come
    back with the whole segment committed, and ``seg_aux`` holds the
    per-position SSM rollback candidates for
    :func:`commit_segment` to roll rejected suffixes back.

    mode="exact": the f32 serving-consistency path — required for the
    token-exactness contract (verification logits must match what
    vanilla f32 decode would have produced).
    """
    B, S = tokens.shape
    x = _embed(params, tokens, cfg)
    if mode == "exact":
        x = x.astype(jnp.float32)
    if lane_mask is not None:
        x = x * lane_mask.astype(x.dtype)[:, None, None]
    x, new_caches, seg_aux = _scan_with_caches(
        params, x, caches, cfg, positions.astype(jnp.int32), mode, constrain,
        prefill=False, collect_aux=True,
    )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head_dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    logits = jnp.einsum(
        "bsd,dv->bsv",
        x.astype(head_dt),
        _lm_head(params, cfg).astype(head_dt),
        preferred_element_type=jnp.float32,
    )
    return softcap(logits, cfg.final_softcap, mode), new_caches, seg_aux


def commit_segment(before, after, seg_aux, cfg: ModelConfig, *,
                   keep_pos, keep_count, active):
    """Merge a verified segment into the cache pool, rolling REJECTED
    positions back bit-for-bit.

    ``before``/``after``: the stacked cache pool as of before/after
    :func:`segment_step` (leaves ``(n_periods, B, ...)``).
    ``seg_aux``: the third return of :func:`segment_step`.
    ``keep_pos`` (B,): last accepted position — cache entries at
    positions ``> keep_pos`` revert to their pre-segment contents
    (which correctly restores even wrapped sliding-window slots the
    segment overwrote).  ``keep_count`` (B,): number of accepted
    segment positions (>= 1 for active lanes).  ``active`` (B,) bool:
    lanes not in the segment keep their ``before`` caches untouched.
    """
    out = {}
    for i, spec in enumerate(cfg.period):
        key = f"pos{i}"
        b, a = before[key], after[key]
        if spec.kind in ("attn", "mla"):
            rejected = (a["pos"] > keep_pos[None, :, None]) | (~active[None, :, None])
            merged = {}
            for name, av in a.items():
                mask = rejected.reshape(rejected.shape + (1,) * (av.ndim - 3))
                merged[name] = jnp.where(mask, b[name], av)
            out[key] = merged
        else:  # mamba: cumulative state — select the per-position candidates
            states = seg_aux[key]["states"]          # (P,B,S,nh,ds,hd) f32
            conv_hist = seg_aux[key]["conv_hist"]    # (P,B,K-1+S,C)
            S = states.shape[2]
            Km1 = conv_hist.shape[2] - S
            idx = jnp.clip(keep_count - 1, 0, S - 1).astype(jnp.int32)
            sel = jnp.take_along_axis(
                states, idx.reshape(1, -1, 1, 1, 1, 1), axis=2
            )[:, :, 0]
            rows = (
                jnp.clip(keep_count, 0, S).astype(jnp.int32).reshape(1, -1, 1, 1)
                + jnp.arange(Km1, dtype=jnp.int32).reshape(1, 1, -1, 1)
            )
            conv = jnp.take_along_axis(conv_hist, jnp.broadcast_to(
                rows, conv_hist.shape[:2] + (Km1, conv_hist.shape[3])), axis=2)
            am = active.reshape(1, -1, 1, 1, 1)
            out[key] = {
                "state": jnp.where(am, sel.astype(b["state"].dtype), b["state"]),
                "conv": jnp.where(am[..., 0], conv.astype(b["conv"].dtype), b["conv"]),
            }
    return out
