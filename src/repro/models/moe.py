"""Mixture-of-Experts with sort-based capacity dispatch.

Design history (recorded as §Perf iteration P1 in EXPERIMENTS.md): the
first implementation used GShard-style one-hot dispatch einsums over
token chunks.  The dry-run roofline exposed two fatal costs at 32k-64k
tokens/device: the (T, E, C) dispatch tensor is O(T^2) and, worse,
chunking re-reads EVERY expert weight once per chunk (x32 weight
traffic/layer for mixtral).  This version dispatches by sorting:

    top-k -> stable argsort by expert -> position-in-expert from the
    sorted order -> GATHER tokens into (E, C, d) -> batched expert
    SwiGLU (weights read ONCE) -> scatter-add back with gate weights.

Dispatch cost becomes O(T k log(T k)) sort + O(T k d) gather/scatter —
no quadratic tensors, no repeated weight reads.

Sharding: TP-within-expert (``mlp`` -> model) by default, since several
assigned archs have expert counts (8, 40) that do not divide 16;
``expert -> data`` in serving layouts where weight memory dominates.

Tokens over capacity ``C = ceil(T k / E * capacity_factor)`` are
dropped (standard); smoke configs use a large factor so the
decode-vs-prefill consistency tests are exact.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (
    Spec,
    attn_norm_spec,
    is_fast_mode,
    pdot,
    psilu,
    rms_norm,
    snap_q8_8,
)

__all__ = ["moe_specs", "moe_forward"]


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "norm": attn_norm_spec(d),
        "router": Spec((d, E), ("embed", None), scale=0.02),
        "w_gate": Spec((E, d, f), ("expert", "embed", "mlp")),
        "w_up": Spec((E, d, f), ("expert", "embed", "mlp")),
        "w_down": Spec((E, f, d), ("expert", "mlp", "embed")),
    }


def _capacity(T: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(T * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _fused_expert_mlp(params, xe):
    """Fused FAST expert SwiGLU on cached int8 weights (serving path).

    The same single-deferred-correction contract as the dense fused MLP
    (kernels/fused_mlp): the gathered tokens are quantized ONCE per
    layer (per-tensor), both expert matmuls run int8 x int8 -> int32
    with per-(expert, out-channel) cached exponents, the CORDIC sigmoid
    is applied to the Q16.16 gate accumulator, and each stage applies
    ONE combined power-of-two correction.  Inference-only (no VJP);
    training keeps the bf16 einsum + STE route.

    xe: (B, E, C, d) gathered tokens -> (B, E, C, d) expert outputs.
    """
    from repro.core.quantization import quantize_pow2
    from repro.kernels.fused_mlp.fused_mlp import swiglu_body_q16

    gq, ge = params["w_gate_q"]["q"], params["w_gate_q"]["exp"]   # (E,d,f), (E,1,f)
    uq, ue = params["w_up_q"]["q"], params["w_up_q"]["exp"]
    dq, de = params["w_down_q"]["q"], params["w_down_q"]["exp"]   # (E,f,d), (E,1,d)
    E, _, f = gq.shape
    d = dq.shape[-1]

    xq = quantize_pow2(xe, bits=8, axis=None)
    # batch over experts: (B,E,C,d) x (E,d,f) -> (E,B,C,f)
    dims_up = (((3,), (1,)), ((1,), (0,)))
    acc_g = jax.lax.dot_general(xq.q, gq, dims_up, preferred_element_type=jnp.int32)
    acc_u = jax.lax.dot_general(xq.q, uq, dims_up, preferred_element_type=jnp.int32)
    e_g = xq.exp + jnp.asarray(ge, jnp.int32).reshape(E, 1, 1, f)
    e_u = xq.exp + jnp.asarray(ue, jnp.int32).reshape(E, 1, 1, f)
    act = swiglu_body_q16(acc_g, acc_u, e_g, e_u)                 # (E,B,C,f) f32

    aq = quantize_pow2(act, bits=8, axis=None)
    # (E,B,C,f) x (E,f,d) -> (E,B,C,d)
    dims_down = (((3,), (1,)), ((0,), (0,)))
    acc_d = jax.lax.dot_general(aq.q, dq, dims_down, preferred_element_type=jnp.int32)
    e_d = (aq.exp + jnp.asarray(de, jnp.int32).reshape(E, 1, 1, d)).astype(jnp.float32)
    ye = acc_d.astype(jnp.float32) * jnp.exp2(e_d)
    return jnp.transpose(ye, (1, 0, 2, 3))                        # (B,E,C,d)


def moe_forward(
    params, x, cfg: ModelConfig, mode: str = "precise", constrain=lambda x, kind: x
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_losses (2,)).

    Routing is BATCH-LOCAL (sort along the sequence axis per batch row,
    capacity per row): a flat global argsort across the data-sharded
    token dimension would compile into a cross-device sort plus a full
    all-gather of activations per layer (measured: +100 GiB/device on
    granite prefill — EXPERIMENTS.md §Perf P3).
    """
    B, S, d = x.shape
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    h = rms_norm(x, params["norm"], cfg.rms_eps)                  # (B,S,d)
    N = S * k
    C = _capacity(S, cfg)

    logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    gate_vals, idx = jax.lax.top_k(probs, k)                      # (B,S,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- sort-based dispatch, per batch row ---------------------------------
    flat_e = idx.reshape(B, N)                                    # (B, S*k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)             # token-order kept
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)   # (B,E)
    seg_start = jnp.cumsum(counts, axis=-1) - counts              # exclusive cumsum
    pos_in_e = (
        jnp.arange(N, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(seg_start, sorted_e, axis=-1).astype(jnp.int32)
    )
    keep = pos_in_e < C
    slot = sorted_e.astype(jnp.int32) * C + pos_in_e              # (B, N) in [0, E*C)
    token = (order // k).astype(jnp.int32)                        # source position

    # scatter source positions into (B, E*C); sentinel S = zero row
    def scatter_ids(slots_row, keep_row, token_row):
        buf = jnp.full((E * C,), S, jnp.int32)
        return buf.at[jnp.where(keep_row, slots_row, E * C)].set(token_row, mode="drop")

    idx_buf = jax.vmap(scatter_ids)(slot, keep, token)            # (B, E*C)
    h_pad = jnp.concatenate([h, jnp.zeros((B, 1, d), h.dtype)], axis=1)
    xe = jnp.take_along_axis(
        h_pad, idx_buf[..., None], axis=1
    ).reshape(B, E, C, d)                                         # GATHER
    # GSPMD's batched-gather/scatter partitioning gives up on the batch
    # dim without explicit constraints, replicating (B, E*C, d) f32
    # tensors per layer (measured: granite prefill 130 GiB/dev,
    # EXPERIMENTS.md §Perf P3b).  Pin batch sharding explicitly:
    xe = constrain(xe, "moe4d")

    # ---- batched expert SwiGLU: weights read ONCE per layer -----------------
    # "exact" (serving) keeps the expert path in f32: a bf16 expert
    # round-trip re-quantizes prefill-vs-decode noise to bf16 ulps,
    # which top-k routing then amplifies into discrete flips.
    dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    if is_fast_mode(mode) and "w_gate_q" in params:
        if mode == "fast8":
            xe = snap_q8_8(xe)
        ye = constrain(_fused_expert_mlp(params, xe).astype(dt), "moe4d")
    else:
        gate = jnp.einsum("becd,edf->becf", xe.astype(dt), params["w_gate"].astype(dt))
        up = jnp.einsum("becd,edf->becf", xe.astype(dt), params["w_up"].astype(dt))
        act = psilu(gate.astype(jnp.float32), mode).astype(dt) * up
        ye = constrain(jnp.einsum("becf,efd->becd", act, params["w_down"].astype(dt)), "moe4d")

    # ---- combine: scatter-add with gate weights ------------------------------
    gate_sorted = jnp.take_along_axis(gate_vals.reshape(B, N), order, axis=-1)
    picked = jnp.take_along_axis(
        ye.reshape(B, E * C, d), jnp.where(keep, slot, 0)[..., None], axis=1
    )
    # bf16 contributions (k-way adds accumulate into an f32 buffer)
    contrib = picked * (gate_sorted * keep).astype(picked.dtype)[..., None]
    contrib = constrain(contrib, "moe3d")

    def combine(token_row, contrib_row):
        return jnp.zeros((S, d), jnp.float32).at[token_row].add(
            contrib_row.astype(jnp.float32)
        )

    y = constrain(jax.vmap(combine)(token, contrib), "residual")   # (B,S,d)

    # ---- aux losses -----------------------------------------------------------
    frac_tokens = jnp.mean(counts.astype(jnp.float32), axis=(0,)) / N * k
    frac_prob = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(frac_tokens * frac_prob) / k
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    return y.astype(x.dtype), jnp.stack([lb, z])
