"""Shared layers: parameter specs with logical sharding axes, RMSNorm,
SwiGLU MLP, rotary embeddings (precise fp32 or fast CORDIC fixed-point),
and the precision-dispatched matmul ``pdot`` — the paper's dispatch
table 𝒟 applied at the op level inside models.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Spec",
    "init_from_specs",
    "rms_norm",
    "softcap",
    "ptanh",
    "psigmoid",
    "psilu",
    "pdot",
    "dot_fast_int8",
    "FAST_MODES",
    "is_fast_mode",
    "snap_q8_8",
    "rope_tables",
    "apply_rope",
    "swiglu_mlp",
    "mlp_specs",
    "attn_norm_spec",
    "WEIGHT_KEYS",
    "attach_quantized_weights",
]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declares one parameter: shape + logical axes + init law.

    ``axes`` are *logical* names ('embed', 'heads', 'mlp', 'vocab',
    'expert', 'ssm', None) resolved to mesh axes by
    repro.distributed.sharding rules at launch time.
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"       # 'normal' | 'zeros' | 'ones' | 'uniform'
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initializer(self, key):
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        if self.init == "uniform":
            return jax.random.uniform(key, self.shape, self.dtype, -scale, scale)
        return jax.random.normal(key, self.shape, self.dtype) * scale


def init_from_specs(specs, key):
    """Materialize a pytree of Specs into parameters (smoke scale only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

#: model-layer dispatch strings that run the Q-format integer path.
#: "fast" is the paper's Q16.16 rung (W8A8 + CORDIC activations);
#: "fast8" is the q8_8 draft rung used by ladder-speculative decoding —
#: same int8 weight payloads, but activations are first rounded onto
#: the Q8.8 grid, a genuinely coarser datapath (values below 2^-8 are
#: lost, headroom saturates at +/-128).
FAST_MODES = ("fast", "fast8")


def is_fast_mode(mode: str) -> bool:
    """True for any Q-format rung ("fast", "fast8")."""
    return mode in FAST_MODES


def snap_q8_8(x):
    """Round onto the Q8.8 grid: 16-bit fixed point, 8 fractional bits,
    saturating at +/-(2^7).  This is the activation coarsening of the
    q8_8 draft rung — applied BEFORE the W8A8 int8 path, it emulates a
    16-bit fixed-point datapath feeding the paper's deferred-correction
    matmul."""
    xf = x.astype(jnp.float32) * 256.0
    xf = jnp.clip(jnp.round(xf), -32768.0, 32767.0)
    return (xf * (1.0 / 256.0)).astype(x.dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    """RMSNorm with fp32 accumulation (precise-path op by policy: norms
    stay on f^F even in FAST mode — the paper's per-op dispatch)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# precision-dispatched activations: 𝒟[tanh] / 𝒟[sigmoid] inside models
# ---------------------------------------------------------------------------
#
# The FAST paths run the universal-CORDIC Q16.16 forward (core/cordic)
# with an analytic-derivative straight-through backward — the same
# custom_vjp pattern as dot_fast_int8 below, so FAST training steps stay
# differentiable even though the forward is integer shift-add.


@jax.custom_vjp
def _tanh_fast(x):
    from repro.core.cordic import cordic_tanh

    return cordic_tanh(x)


def _tanh_fast_fwd(x):
    y = _tanh_fast(x)
    return y, y


def _tanh_fast_bwd(y, g):
    return (g * (1.0 - y * y),)


_tanh_fast.defvjp(_tanh_fast_fwd, _tanh_fast_bwd)


@jax.custom_vjp
def _sigmoid_fast(x):
    from repro.core.cordic import cordic_sigmoid

    return cordic_sigmoid(x)


def _sigmoid_fast_fwd(x):
    y = _sigmoid_fast(x)
    return y, y


def _sigmoid_fast_bwd(y, g):
    return (g * y * (1.0 - y),)


_sigmoid_fast.defvjp(_sigmoid_fast_fwd, _sigmoid_fast_bwd)


def ptanh(x, mode: str = "precise"):
    """𝒟[tanh]: FAST -> Q16.16 CORDIC (|eps| <= 6e-5, STE backward);
    PRECISE -> IEEE-754.  Inputs are expected in f32."""
    if is_fast_mode(mode):
        return _tanh_fast(x)
    return jnp.tanh(x)


def psigmoid(x, mode: str = "precise"):
    """𝒟[sigmoid]: FAST -> Q16.16 CORDIC (|eps| <= 5e-5, STE backward)."""
    if is_fast_mode(mode):
        return _sigmoid_fast(x)
    return jax.nn.sigmoid(x)


def psilu(x, mode: str = "precise"):
    """𝒟[silu]: x * sigmoid(x) with the sigmoid precision-dispatched;
    the product rule composes with the sigmoid STE under autodiff."""
    if is_fast_mode(mode):
        return x * _sigmoid_fast(x)
    return jax.nn.silu(x)


def softcap(x, cap: Optional[float], mode: str = "precise"):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap), with the tanh
    precision-dispatched.  Attention-*score* capping call sites stay
    PRECISE by policy (like rms_norm: tiny f32 internals where a
    format boundary would cost more than it saves)."""
    if cap is None:
        return x
    return (cap * ptanh(x.astype(jnp.float32) / cap, mode)).astype(x.dtype)


# ---------------------------------------------------------------------------
# precision-dispatched matmul (the per-op 𝒟 inside models)
# ---------------------------------------------------------------------------


def _quant_dims(x, w):
    """per-tensor activation exponent, per-out-channel weight exponents."""
    from repro.core.quantization import quantize_pow2

    xq = quantize_pow2(x, bits=8, axis=None)
    wq = quantize_pow2(w, bits=8, axis=w.ndim - 1)
    return xq, wq


@jax.custom_vjp
def _dot_fast(x, w):
    return _dot_fast_fwd_impl(x, w)


def _dot_fast_fwd_impl(x, w):
    xq, wq = _quant_dims(x, w)
    acc = jax.lax.dot_general(
        xq.q,
        wq.q,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    e = (xq.exp + wq.exp.reshape(-1)).astype(jnp.float32)
    return acc.astype(jnp.float32) * jnp.exp2(e)


def _dot_fast_fwd(x, w):
    return _dot_fast_fwd_impl(x, w), (x, w)


def _dot_fast_bwd(res, g):
    x, w = res
    g = g.astype(jnp.float32)
    gx = jax.lax.dot_general(
        g, w.astype(jnp.float32), (((g.ndim - 1,), (1,)), ((), ()))
    ).astype(x.dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = g.reshape(-1, g.shape[-1])
    gw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return gx, gw


_dot_fast.defvjp(_dot_fast_fwd, _dot_fast_bwd)


def _wq_parts(wq):
    """Normalize a pre-quantized weight operand: QTensor or the
    ``{"q": int8, "exp": int32}`` dict stored in augmented param trees."""
    if isinstance(wq, dict):
        return wq["q"], wq["exp"]
    return wq.q, wq.exp


@jax.custom_vjp
def _dot_fast_cached(x, w, q, e):
    return _dot_fast_cached_impl(x, q, e)


def _dot_fast_cached_impl(x, q, e):
    from repro.core.quantization import quantize_pow2

    xq = quantize_pow2(x, bits=8, axis=None)
    acc = jax.lax.dot_general(
        xq.q,
        q,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    ee = (xq.exp + jnp.asarray(e, jnp.int32).reshape(-1)).astype(jnp.float32)
    return acc.astype(jnp.float32) * jnp.exp2(ee)


def _dot_fast_cached_fwd(x, w, q, e):
    import numpy as np

    # integer operands carry float0 cotangents; stash them concrete
    zeros = (
        np.zeros(q.shape, jax.dtypes.float0),
        np.zeros(e.shape, jax.dtypes.float0),
    )
    return _dot_fast_cached_impl(x, q, e), (x, w, zeros)


def _dot_fast_cached_bwd(res, g):
    x, w, (zq, ze) = res
    gx, gw = _dot_fast_bwd((x, w), g)
    return gx, gw, zq, ze


_dot_fast_cached.defvjp(_dot_fast_cached_fwd, _dot_fast_cached_bwd)


def dot_fast_int8(x, w, wq=None):
    """W8A8 matmul, kernel-equivalent XLA form: int8 x int8 -> int32 MXU
    accumulation, ONE deferred power-of-two rescale (paper C3).

    This is the exact computation the Pallas kernel
    (kernels/qmatmul) performs on real TPU; expressed as
    ``lax.dot_general(..., preferred_element_type=int32)`` it lowers on
    every backend and is what the multi-pod dry-run compiles.  Backward
    is the straight-through estimator (float grads).

    ``wq`` (optional) is a pre-quantized weight operand (QTensor or the
    ``{"q", "exp"}`` dict a :class:`~repro.core.quantization.\
QuantizedWeightCache` attaches to param trees): the per-call weight
    quantization is skipped entirely — bit-identical to the uncached
    path for the same weights, but the decode loop never requantizes.
    """
    if wq is None:
        return _dot_fast(x, w)
    q, e = _wq_parts(wq)
    return _dot_fast_cached(x, w, q, e)


def pdot(x, w, mode: str = "precise", wq=None):
    """𝒟[matmul]: FAST -> W8A8 deferred-rescale path; PRECISE -> bf16
    MXU (per-device f32 accumulation is implicit in the TPU MXU);
    EXACT -> f32 end-to-end (serving-consistency mode, see below).

    Deliberately bf16-in/bf16-out on the PRECISE path, with NO
    preferred_element_type=f32 + downcast: that pattern pins every TP
    partial-sum all-reduce and every backward reshard to fp32 (XLA
    cannot commute the convert through the reduction), doubling
    collective bytes.  Cross-device partial sums in bf16 are the
    Megatron-standard trade.

    EXACT is the *serving* precise path (runtime/serve maps the ``f32``
    ladder level here): a bf16-rounded output quantizes the tiny
    shape-dependent accumulation differences between a (B, S) prefill
    gemm and a (B, 1) decode gemm up to a full bf16 ulp — and at
    hybrid-depth residual magnitudes one residual-stream ulp is O(10),
    which is what made jamba's decode drift from its own prefill
    re-derivation (ROADMAP "Known-failing tier-1 tests").  Keeping the
    serving matmuls in f32 keeps that noise at f32 scale, so greedy
    decode agrees with prefill re-derivation across all families.

    ``wq``: optional cached int8 weights — used by the FAST path only.
    """
    if is_fast_mode(mode):
        if mode == "fast8":
            x = snap_q8_8(x)
        return dot_fast_int8(x, w, wq=wq).astype(jnp.bfloat16)
    dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    return jax.lax.dot_general(
        x.astype(dt),
        w.astype(dt),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
    )


# ---------------------------------------------------------------------------
# rotary embeddings: 𝒟[sin/cos]
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rope_dim", "base", "mode"))
def rope_tables(positions, rope_dim: int, base: float = 10000.0, mode: str = "precise"):
    """(… ) int positions -> (…, rope_dim//2) sin/cos tables.

    PRECISE: fp32 ``jnp.sin/cos`` of ``pos * inv_freq``.
    FAST: exact Q0.64 phase accumulation + 16-iteration CORDIC
    (core/cordic) — integer-only, and *more accurate* than the fp32
    path at long-context positions (tests/test_cordic.py).
    """
    half = rope_dim // 2
    if is_fast_mode(mode):
        from repro.core.cordic import exact_rope_phase_q16, cordic_sincos_q16, rope_inv_freq_q64
        from repro.core.qformat import Q16_16, from_fixed

        f_hi, f_lo = rope_inv_freq_q64(rope_dim, base)
        theta_q = exact_rope_phase_q16(
            positions[..., None], jnp.asarray(f_hi)[None, :], jnp.asarray(f_lo)[None, :]
        )
        sin_q, cos_q = cordic_sincos_q16(theta_q)
        return from_fixed(sin_q, Q16_16), from_fixed(cos_q, Q16_16)
    inv_freq = (base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / rope_dim))
    angle = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.sin(angle), jnp.cos(angle)


def apply_rope(x, sin, cos):
    """x: (..., S, H, D); sin/cos: (..., S, D//2) broadcast over heads.
    Half-split (llama) convention."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "norm": Spec((d_model,), ("embed",), init="zeros"),
        "w_gate": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_up": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_down": Spec((d_ff, d_model), ("mlp", "embed")),
    }


def attn_norm_spec(d_model: int) -> Spec:
    return Spec((d_model,), ("embed",), init="zeros")


def _fused_swiglu_fast(h, wgq, wuq):
    """Fused FAST hidden stage on cached int8 weights: one x
    quantization feeding both matmuls, then the kernel-equivalent XLA
    form (kernels/fused_mlp.fused_swiglu_xla — CORDIC sigmoid on the
    Q16.16 gate accumulator, ONE combined power-of-two correction).
    Inference-only: the int8 dots have no VJP; training keeps the
    per-call STE path below.
    """
    from repro.core.quantization import quantize_pow2
    from repro.kernels.fused_mlp.ops import fused_swiglu_xla

    gq, ge = _wq_parts(wgq)
    uq, ue = _wq_parts(wuq)
    xq = quantize_pow2(h, bits=8, axis=None)
    return fused_swiglu_xla(xq.q, gq, uq, xq.exp, ge, ue)


def swiglu_mlp(params, x, mode: str = "precise", eps: float = 1e-5):
    """SwiGLU MLP with the paper's per-op dispatch.

    FAST with cached quantized weights attached (``w_gate_q`` etc., see
    :func:`attach_quantized_weights`): the fused hidden stage — one
    activation quantization, no weight requantization, the activation
    never round-tripping through bf16 — then the down-projection on the
    cached int8 ``w_down`` (one more deferred correction).  Otherwise
    the original three-dispatch path (the training/default route).
    """
    h = rms_norm(x, params["norm"], eps)
    if is_fast_mode(mode) and "w_gate_q" in params:
        if mode == "fast8":
            h = snap_q8_8(h)
        act = _fused_swiglu_fast(h, params["w_gate_q"], params["w_up_q"])
        act = act.astype(jnp.bfloat16)
        return pdot(act, params["w_down"], mode, wq=params["w_down_q"])
    gate = pdot(h, params["w_gate"], mode)
    up = pdot(h, params["w_up"], mode)
    act = psilu(gate.astype(jnp.float32), mode).astype(up.dtype) * up
    return pdot(act, params["w_down"], mode)


# ---------------------------------------------------------------------------
# quantize-once weight attachment (serving FAST path)
# ---------------------------------------------------------------------------

#: param-dict keys consumed through ``pdot`` / the fused MLP-MoE paths.
#: (MLA's ``wkv_b`` is read through absorbed-decode einsums, not pdot,
#: so it stays float.)
WEIGHT_KEYS = frozenset({
    "w_gate", "w_up", "w_down",            # MLP + MoE experts
    "wq", "wk", "wv", "wo",                # attention projections
    "wq_a", "wq_b", "wkv_a",               # MLA low-rank projections
    "wz", "wx", "wB", "wC", "wdt",         # Mamba-2 projections
})


def attach_quantized_weights(params, cache, *, level: str = "q16_16"):
    """Return ``params`` with ``<key>_q = {"q": int8, "exp": int32}``
    entries added next to every :data:`WEIGHT_KEYS` matrix, quantized
    ONCE through ``cache`` (a QuantizedWeightCache — normally
    ``engine.weight_cache``).

    The exponent axes are "everything except the contraction axis"
    (``ndim-2``): per out-channel for 2-D weights, additionally per
    period for scanned stacks, per (period, expert) for MoE — so the
    scanned slice of every added leaf broadcasts exactly like the
    per-call quantization it replaces.  Float leaves are left in place
    (precise path, STE backward, and re-attachment after
    ``engine.invalidate_weights`` all still need them).
    """
    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, f"{path}/{k}") for k, v in node.items()}
            for k in sorted(WEIGHT_KEYS & node.keys()):
                w = node[k]
                if not hasattr(w, "ndim") or w.ndim < 2:
                    continue
                axis = tuple(i for i in range(w.ndim) if i != w.ndim - 2)
                qt = cache.get(f"{path}/{k}", w, level=level, axis=axis)
                out[k + "_q"] = {"q": qt.q, "exp": qt.exp}
            return out
        return node

    return walk(params, "")
