"""Period-level block composition.

A *period* is the repeating unit of layers (1 for homogeneous stacks,
2 for gemma2 local/global, 8 for jamba's 1-attention:7-mamba pattern).
The model scans over ``n_periods`` stacked parameter pytrees, keeping
HLO size independent of depth; inside the scanned body a static Python
loop walks the period's heterogeneous positions.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import mlp_specs, swiglu_mlp

__all__ = ["period_specs", "period_forward", "init_period_cache"]

Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]
_id_constrain: Constrain = lambda x, kind: x


def _mixer_specs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.kind == "attn":
        return attn.attn_specs(cfg)
    if spec.kind == "mla":
        return attn.mla_specs(cfg)
    if spec.kind == "mamba":
        return ssm_mod.ssm_specs(cfg)
    raise ValueError(spec.kind)


def _ffn_specs(cfg: ModelConfig, spec: LayerSpec) -> Optional[dict]:
    if spec.ffn == "mlp":
        return mlp_specs(cfg.d_model, cfg.d_ff)
    if spec.ffn == "moe":
        return moe_mod.moe_specs(cfg)
    return None


def period_specs(cfg: ModelConfig) -> dict:
    out = {}
    for i, spec in enumerate(cfg.period):
        entry = {"mixer": _mixer_specs(cfg, spec)}
        f = _ffn_specs(cfg, spec)
        if f is not None:
            entry["ffn"] = f
        out[f"pos{i}"] = entry
    return out


def init_period_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
    quantized: bool = False,
) -> dict:
    """Cache pytree for ONE period (stacked over periods by the caller).
    ``quantized``: Q-format int8 KV payloads (FAST serving mode)."""
    out = {}
    for i, spec in enumerate(cfg.period):
        if spec.kind == "attn":
            out[f"pos{i}"] = attn.init_attn_cache(
                cfg, spec, batch, max_len, dtype, quantized=quantized
            )
        elif spec.kind == "mla":
            out[f"pos{i}"] = attn.init_mla_cache(cfg, batch, max_len, dtype)
        elif spec.kind == "mamba":
            out[f"pos{i}"] = ssm_mod.init_ssm_cache(cfg, batch)
    return out


def period_forward(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    mode: str = "precise",
    caches: Optional[dict] = None,
    prefill: bool = False,
    constrain: Constrain = _id_constrain,
    seg_aux: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Apply one period. Returns (x, new_caches, aux_losses (2,)).

    ``seg_aux``: mutable dict for segment-decode rollback state.  When
    given (speculative verify), each SSM layer records its per-position
    states under ``seg_aux[f"pos{i}"]`` so the caller can roll the
    cumulative cache back to any position in the segment."""
    aux = jnp.zeros((2,), jnp.float32)
    new_caches = {} if caches is not None else None

    for i, spec in enumerate(cfg.period):
        p = params[f"pos{i}"]
        cache_i = caches.get(f"pos{i}") if caches is not None else None

        if spec.kind == "attn":
            h, c = attn.attention_forward(
                p["mixer"], x, cfg, spec,
                positions=positions, mode=mode, cache=cache_i, prefill=prefill,
                constrain=constrain,
            )
        elif spec.kind == "mla":
            h, c = attn.mla_forward(
                p["mixer"], x, cfg,
                positions=positions, mode=mode, cache=cache_i, prefill=prefill,
                constrain=constrain,
            )
        else:  # mamba
            layer_aux = {} if seg_aux is not None else None
            h, c = ssm_mod.ssm_forward(
                p["mixer"], x, cfg, mode=mode, cache=cache_i, prefill=prefill,
                constrain=constrain, seg_aux=layer_aux,
            )
            if seg_aux is not None:
                seg_aux[f"pos{i}"] = layer_aux
        x = constrain(x + h, "residual")

        if "ffn" in p:
            if spec.ffn == "moe":
                h, a = moe_mod.moe_forward(p["ffn"], x, cfg, mode, constrain=constrain)
                aux = aux + a
            else:
                h = swiglu_mlp(p["ffn"], x, mode, cfg.rms_eps)
            x = constrain(x + h, "residual")

        if new_caches is not None:
            new_caches[f"pos{i}"] = c
    return x, new_caches, aux
