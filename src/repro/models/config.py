"""Model configuration: one dataclass family covering all ten assigned
architectures (dense / GQA / MLA / SWA / local-global / MoE / SSD /
hybrid / modality-stub backbones).

A model is ``n_periods`` repetitions of a *period* — an ordered list of
``LayerSpec``s.  Homogeneous stacks (deepseek) have a 1-layer period;
gemma2 has a 2-layer period (local, global); jamba an 8-layer period
(1 attention + 7 mamba, MoE on odd positions).  Periods are scanned
with stacked parameters, keeping HLO size and compile time independent
of depth.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["AttnKind", "LayerSpec", "MoEConfig", "MLAConfig", "SSMConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    # token chunk for the capacity-dispatch einsum (memory bound)
    dispatch_chunk: int = 2048


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128          # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position within a period."""

    kind: str = "attn"                 # 'attn' | 'mla' | 'mamba'
    window: Optional[int] = None       # None = full attention; int = SWA
    ffn: str = "mlp"                   # 'mlp' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    period: Tuple[LayerSpec, ...]      # len(period) must divide n_layers
    vocab: int
    n_heads: int = 0                   # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rope_base: float = 10000.0
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    tie_embeddings: bool = False
    rms_eps: float = 1e-5
    modality_stub: Optional[str] = None     # None | 'vision' | 'audio'
    stub_prefix_len: int = 0                # patch/frame positions for stubs
    max_seq: int = 32768

    # -- derived ------------------------------------------------------------

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (self.name, self.n_layers, len(self.period))
        return self.n_layers // len(self.period)

    @property
    def qk_head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
        return self.head_dim

    @property
    def v_head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.v_head_dim
        return self.head_dim

    @property
    def rope_dim(self) -> int:
        """Number of rotary dimensions per head."""
        if self.mla is not None:
            return self.mla.qk_rope_head_dim
        return self.head_dim

    @property
    def has_attention(self) -> bool:
        return any(s.kind in ("attn", "mla") for s in self.period)

    @property
    def is_subquadratic(self) -> bool:
        """Assignment rule for long_500k: run for SSM / hybrid /
        sliding-window archs; skip only *pure full-attention* stacks.
        Hybrids (jamba: 7/8 mamba + 1/8 full attention) run — their
        attention caches are context-parallel sharded over the data
        axis (see launch/steps._cache_shardings)."""
        has_ssm = any(s.kind == "mamba" for s in self.period)
        all_windowed = all(s.kind == "mamba" or s.window is not None for s in self.period)
        return has_ssm or all_windowed

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        from repro.models.model import param_shapes  # local: avoids cycle
        import math

        total = 0
        for leaf in _iter_leaves(param_shapes(self)):
            total += math.prod(leaf.shape)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts top_k experts)."""
        from repro.models.model import param_shapes
        import math

        total = 0
        for _path, leaf in _iter_items(param_shapes(self)):
            n = math.prod(leaf.shape)
            if self.moe and "expert" in (leaf.axes or ()):
                n = n * self.moe.top_k // self.moe.num_experts
            total += n
        return total


def _iter_leaves(tree):
    for _, leaf in _iter_items(tree):
        yield leaf


def _iter_items(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_items(v, prefix + "/" + str(k))
    else:
        yield prefix, tree


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduce any arch config to CPU-smoke scale, preserving the family
    structure (period pattern, MoE top-k, MLA ranks scaled, SSD heads)."""
    moe = None
    if cfg.moe:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            # effectively dropless so prefill == incremental decode in
            # the consistency tests (production keeps 1.25 + drops)
            capacity_factor=8.0,
            dispatch_chunk=64,
        )
    mla = None
    if cfg.mla:
        mla = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
            qk_rope_head_dim=8, v_head_dim=8,
        )
    ssm = None
    if cfg.ssm:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=8, chunk=16)
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = min(cfg.n_kv_heads, max(1, n_heads // 2)) if cfg.n_kv_heads else 0
    period = tuple(
        dataclasses.replace(s, window=(8 if s.window else None)) for s in cfg.period
    )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=64,
        n_layers=2 * len(cfg.period),
        period=period,
        vocab=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=96 if cfg.d_ff else 0,
        moe=moe,
        mla=mla,
        ssm=ssm,
        stub_prefix_len=4 if cfg.modality_stub else 0,
        max_seq=64,
    )
