"""Attention: GQA / sliding-window / local-global / MLA, with a
memory-safe chunked online-softmax formulation (scan over KV blocks) so
32k-token prefill never materializes an S x S score matrix.

Decode (single query against a cache) materializes the (B, H, S_kv)
score row directly — it is linear in S_kv and small.

Sliding-window caches are rolling buffers of size ``window`` with an
explicit per-slot position tensor (mask handles wrap-around), so
mixtral's 32k/500k decode memory is window-bounded.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (
    Spec,
    apply_rope,
    attn_norm_spec,
    pdot,
    rms_norm,
    rope_tables,
    softcap,
)

__all__ = [
    "attn_specs",
    "mla_specs",
    "attention_forward",
    "mla_forward",
    "init_attn_cache",
    "init_mla_cache",
    "reset_attn_cache_slot",
    "reset_mla_cache_slot",
    "truncate_attn_cache_slot",
]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "norm": attn_norm_spec(d),
        "wq": Spec((d, h * hd), ("embed", "heads")),
        "wk": Spec((d, kv * hd), ("embed", "kv")),
        "wv": Spec((d, kv * hd), ("embed", "kv")),
        "wo": Spec((h * hd, d), ("heads", "embed")),
    }


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "norm": attn_norm_spec(d),
        "wq_a": Spec((d, m.q_lora_rank), ("embed", None)),
        "q_norm": Spec((m.q_lora_rank,), (None,), init="zeros"),
        "wq_b": Spec((m.q_lora_rank, h * qk), (None, "heads")),
        "wkv_a": Spec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": Spec((m.kv_lora_rank,), (None,), init="zeros"),
        "wkv_b": Spec((m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)), (None, "heads")),
        "wo": Spec((h * m.v_head_dim, d), ("heads", "embed")),
    }


# ---------------------------------------------------------------------------
# chunked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------


def _gqa_scores(q, k):
    """q: (B,S,KV,G,D), k: (B,Ck,KV,D) -> (B,KV,G,S,Ck) fp32."""
    return jnp.einsum("bskgd,bckd->bkgsc", q, k, preferred_element_type=jnp.float32)


def chunked_attention(
    q,
    k,
    v,
    *,
    q_positions,
    kv_len: Optional[int] = None,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    chunk: int = 1024,
):
    """q: (B,S,H,Dq); k: (B,Skv,KV,Dq); v: (B,Skv,KV,Dv).

    Online softmax over KV chunks: memory O(S * chunk) instead of
    O(S * Skv).  Keys are assumed contiguous from position 0 (training
    and prefill), so key positions are derived from the chunk index
    *inside* the scanned body — this keeps the mask loop-variant (XLA
    would otherwise hoist an O(n_chunks * S * chunk) mask tensor out of
    the loop) — and the body is checkpointed, so the backward pass
    recomputes scores/masks instead of saving them (flash-attention
    memory behavior, pure JAX).
    """
    B, S, H, Dq = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    scale = 1.0 / math.sqrt(Dq)
    kv_len = Skv if kv_len is None else kv_len

    if Skv >= 32768:
        chunk = min(chunk, 128)  # bound the f32 score buffers at 32k prefill
    elif Skv >= 16384:
        chunk = min(chunk, 512)
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qr = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(B, S, KV, G, Dq)
    k_c = k.reshape(B, n_chunks, chunk, KV, Dq).swapaxes(0, 1)
    v_c = v.reshape(B, n_chunks, chunk, KV, Dv).swapaxes(0, 1)

    def step(carry, blk):
        m, l, acc = carry
        idx, k_blk, v_blk = blk  # (), (B,chunk,KV,D), (B,chunk,KV,Dv)
        s = _gqa_scores(qr, k_blk)  # (B,KV,G,S,chunk) f32
        s = softcap(s, cap)
        # key positions derived from the chunk index (loop-variant)
        kp = idx * chunk + jax.lax.iota(jnp.int32, chunk)          # (chunk,)
        qp = q_positions[:, None, None, :, None]                   # (B,1,1,S,1)
        kpb = kp[None, None, None, None, :]
        valid = kpb < kv_len
        if causal:
            valid = valid & (kpb <= qp)
        if window is not None:
            valid = valid & (kpb > qp - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bkgsc,bckd->bkgsd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False),
        (m0, l0, a0),
        (jnp.arange(n_chunks, dtype=jnp.int32), k_c, v_c),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kp, *, q_position, window=None, cap=None,
                     k_exp=None, v_exp=None):
    """Single-token decode: q (B,1,H,Dq) vs cache (B,L,KV,D); kp (B,L)
    slot positions (-1 = unwritten).

    Q-format caches (k_exp/v_exp per slot): the int8 payload enters the
    dot via a fused convert; the power-of-two exponents fold into the
    scores / probabilities (shift-only, C1's deferred correction)."""
    B, _, H, Dq = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dq)
    qr = (q[:, 0] * scale).reshape(B, KV, G, Dq)
    s = jnp.einsum(
        "bkgd,blkd->bkgl", qr.astype(jnp.float32), k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if k_exp is not None:  # (B, L, KV) -> (B, KV, 1, L)
        s = s * jnp.exp2(k_exp.astype(jnp.float32)).transpose(0, 2, 1)[:, :, None, :]
    s = softcap(s, cap)
    qp = q_position[:, None, None, None]
    kpb = kp[:, None, None, :]
    valid = (kpb >= 0) & (kpb <= qp)
    if window is not None:
        valid &= kpb > qp - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_exp is not None:
        p = p * jnp.exp2(v_exp.astype(jnp.float32)).transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgl,blkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, -1).astype(q.dtype)


def decode_attention_multi(q, k_cache, v_cache, kp, *, q_positions, window=None,
                           cap=None, k_exp=None, v_exp=None):
    """Segment decode: S queries against a cache (the speculative-verify
    / chunked-continuation path).  q (B,S,H,Dq) vs cache (B,L,KV,D);
    kp (B,L) slot positions (-1 = unwritten); q_positions (B,S).

    Each query position masks keys by its OWN position (kp <= qp_s), so
    within-segment causality holds after the whole segment's k/v have
    been written to the cache.  The (B,KV,G,S,L) score tensor is small
    for decode-length segments (S = k+1 speculative drafts)."""
    B, S, H, Dq = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dq)
    qr = (q.astype(jnp.float32) * scale).reshape(B, S, KV, G, Dq)
    s = jnp.einsum(
        "bskgd,blkd->bkgsl", qr, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if k_exp is not None:  # (B, L, KV) -> (B, KV, 1, 1, L)
        s = s * jnp.exp2(k_exp.astype(jnp.float32)).transpose(0, 2, 1)[:, :, None, None, :]
    s = softcap(s, cap)
    qp = q_positions[:, None, None, :, None]                   # (B,1,1,S,1)
    kpb = kp[:, None, None, None, :]                           # (B,1,1,1,L)
    valid = (kpb >= 0) & (kpb <= qp)
    if window is not None:
        valid &= kpb > qp - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_exp is not None:
        p = p * jnp.exp2(v_exp.astype(jnp.float32)).transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgsl,blkd->bskgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, S, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer forward (train/prefill and decode)
# ---------------------------------------------------------------------------


def init_attn_cache(
    cfg: ModelConfig, layer: LayerSpec, batch: int, max_len: int,
    dtype=jnp.bfloat16, quantized: bool = False,
):
    """quantized=True: the paper's Q-format applied to the KV cache —
    int8 payloads with a per-(batch, slot) power-of-two exponent
    (shift-only rescale, C1 faithful).  Halves resident cache bytes;
    the dequant scales fold into the attention dots."""
    L = min(layer.window, max_len) if layer.window else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    out = {
        "k": jnp.zeros((batch, L, kv, hd), jnp.int8 if quantized else dtype),
        "v": jnp.zeros((batch, L, kv, hd), jnp.int8 if quantized else dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
    }
    if quantized:
        # per-(slot, kv-head) exponents: finer than per-slot, still
        # negligible overhead (L x KV int32 vs L x KV x hd int8 payload)
        out["k_exp"] = jnp.zeros((batch, L, kv), jnp.int32)
        out["v_exp"] = jnp.zeros((batch, L, kv), jnp.int32)
    return out


def reset_attn_cache_slot(cache: dict, slot) -> dict:
    """Reset one batch slot of a KV cache for continuous-batching
    admission.  Payloads zero; the per-slot position tensor goes back
    to -1 (unwritten) so the next occupant's decode mask cannot attend
    to the evicted request's residue.  ``slot`` may be traced."""
    out = {}
    for k, v in cache.items():
        fill = jnp.full(v.shape[1:], -1, v.dtype) if k == "pos" else jnp.zeros(v.shape[1:], v.dtype)
        out[k] = v.at[slot].set(fill)
    return out


def reset_mla_cache_slot(cache: dict, slot) -> dict:
    """MLA variant of :func:`reset_attn_cache_slot` (latent ckv/krope
    payloads + the same -1 position sentinel)."""
    return reset_attn_cache_slot(cache, slot)


def truncate_attn_cache_slot(cache: dict, slot, keep_pos) -> dict:
    """Truncate-to-position form of :func:`reset_attn_cache_slot`:
    entries of ONE batch slot whose position is ``>= keep_pos`` go back
    to the pristine fill (payloads zero, pos sentinel -1); entries below
    the boundary are untouched BIT-FOR-BIT.  This is the speculative-
    decoding rollback for position-indexed caches (GQA k/v and MLA
    ckv/krope both carry the same per-slot ``pos`` tensor, so one
    implementation serves both).  ``slot`` and ``keep_pos`` may be
    traced — jit-safe.

    NOTE: this restores a *pristine* fill, which equals the pre-write
    contents only while the rolling buffer has not wrapped (position
    ``>= keep_pos`` was never previously occupied by an OLDER live
    entry).  Wrapped sliding-window rollback needs the before/after
    merge in :func:`repro.models.model.commit_segment`, which keeps the
    overwritten entries."""
    out = {}
    drop = cache["pos"][slot] >= keep_pos                      # (L,)
    for k, v in cache.items():
        row = v[slot]
        fill = jnp.asarray(-1 if k == "pos" else 0, v.dtype)
        mask = drop.reshape((-1,) + (1,) * (row.ndim - 1))
        out[k] = v.at[slot].set(jnp.where(mask, fill, row))
    return out


def _q8(x, axes):
    """int8 KV-cache quantization on the paper's pow2 grid: one
    exponent per kept slice — per (batch[, seq], kv-head), with the
    reduced ``axes`` spanning head_dim.  Defers to the core
    quantizer's kept-axes form so cache payloads and weight/activation
    quantization share a single grid definition.  Returns
    ``(int8 payload, exponents)`` with the exponents' reduced axes
    squeezed away (the cache's ``k_exp``/``v_exp`` layout)."""
    from repro.core.quantization import quantize_pow2

    red = {a % x.ndim for a in axes}
    keep = tuple(i for i in range(x.ndim) if i not in red)
    qt = quantize_pow2(x, bits=8, axis=keep)
    return qt.q, qt.exp.reshape([x.shape[i] for i in keep])


def attention_forward(
    params,
    x,
    cfg: ModelConfig,
    layer: LayerSpec,
    *,
    positions,
    mode: str = "precise",
    cache=None,
    prefill: bool = False,
    constrain=lambda x, kind: x,
):
    """x: (B,S,d).

    cache=None             -> training forward (no cache out)
    cache given, prefill   -> chunked attention + cache populated [0:S)
    cache given, S==1      -> single-token decode against the cache
    """
    B, S, _ = x.shape
    h = rms_norm(x, params["norm"], cfg.rms_eps)
    q = pdot(h, params["wq"], mode, wq=params.get("wq_q")).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = pdot(h, params["wk"], mode, wq=params.get("wk_q")).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = pdot(h, params["wv"], mode, wq=params.get("wv_q")).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)

    sin, cos = rope_tables(positions, cfg.head_dim, cfg.rope_base, mode)
    # head-sharded (TP) layout through attention: keeps every KV chunk
    # local to its device; the seq<->heads reshard happens ONCE per
    # layer, outside the chunk loop (see launch/steps._make_constrain)
    q = constrain(apply_rope(q, sin, cos), "heads4d")
    k = constrain(apply_rope(k, sin, cos), "heads4d")
    v = constrain(v, "heads4d")

    if cache is None or prefill:
        out = chunked_attention(
            q, k, v,
            q_positions=positions,
            causal=True,
            window=layer.window,
            cap=cfg.attn_softcap,
        )
        new_cache = None
        if prefill:
            new_cache = _prefill_cache(cache, k, v, positions, layer.window)
    elif S == 1:
        L = cache["k"].shape[1]
        slot = positions[:, 0] % L  # rolling for SWA; L==max_len handles full
        quantized = "k_exp" in cache
        if quantized:
            qk, e_k = _q8(k[:, 0], axes=(2,))            # exps (B, KV)
            qv, e_v = _q8(v[:, 0], axes=(2,))
            k_cache = _store(cache["k"], qk, slot)
            v_cache = _store(cache["v"], qv, slot)
            ek_c = _store(cache["k_exp"], e_k, slot)
            ev_c = _store(cache["v_exp"], e_v, slot)
        else:
            k_cache = _store(cache["k"], k[:, 0], slot)
            v_cache = _store(cache["v"], v[:, 0], slot)
            ek_c = ev_c = None
        kp = _store(cache["pos"], positions[:, 0], slot)
        out = decode_attention(
            q, k_cache, v_cache, kp,
            q_position=positions[:, 0],
            window=layer.window,
            cap=cfg.attn_softcap,
            k_exp=ek_c, v_exp=ev_c,
        )
        new_cache = {"k": k_cache, "v": v_cache, "pos": kp}
        if quantized:
            new_cache["k_exp"] = ek_c
            new_cache["v_exp"] = ev_c
    else:
        # segment decode (speculative verify): S tokens against the
        # cache with per-query causal masks.  Requires S <= L so the
        # segment cannot overwrite its own earlier writes.
        L = cache["k"].shape[1]
        if S > L:
            raise ValueError(f"segment length {S} exceeds cache length {L}")
        quantized = "k_exp" in cache
        k_cache, v_cache = cache["k"], cache["v"]
        kp = cache["pos"]
        ek_c = cache.get("k_exp")
        ev_c = cache.get("v_exp")

        def store_one(s_i):
            nonlocal k_cache, v_cache, kp, ek_c, ev_c
            slot = positions[:, s_i] % L
            if quantized:
                qk, e_k = _q8(k[:, s_i], axes=(2,))      # exps (B, KV)
                qv, e_v = _q8(v[:, s_i], axes=(2,))
                k_cache = _store(k_cache, qk, slot)
                v_cache = _store(v_cache, qv, slot)
                ek_c = _store(ek_c, e_k, slot)
                ev_c = _store(ev_c, e_v, slot)
            else:
                k_cache = _store(k_cache, k[:, s_i], slot)
                v_cache = _store(v_cache, v[:, s_i], slot)
            kp = _store(kp, positions[:, s_i], slot)

        if layer.window is None:
            # full attention: positions stay below L, so no in-segment
            # write can land on a slot an earlier query needs — write the
            # whole segment, then batch the S queries (bit-matches the
            # sequential decode order: same slots, same masked set).
            for s_i in range(S):
                store_one(s_i)
            out = decode_attention_multi(
                q, k_cache, v_cache, kp,
                q_positions=positions,
                window=None,
                cap=cfg.attn_softcap,
                k_exp=ek_c, v_exp=ev_c,
            )
        else:
            # SWA rolling buffer: a later segment write can WRAP onto a
            # slot an earlier query's window still covers.  Interleave
            # store/query exactly as sequential decode does (S is static
            # and small — at most k+1 speculative positions).
            outs = []
            for s_i in range(S):
                store_one(s_i)
                outs.append(decode_attention(
                    q[:, s_i : s_i + 1], k_cache, v_cache, kp,
                    q_position=positions[:, s_i],
                    window=layer.window,
                    cap=cfg.attn_softcap,
                    k_exp=ek_c, v_exp=ev_c,
                ))
            out = jnp.concatenate(outs, axis=1)
        new_cache = {"k": k_cache, "v": v_cache, "pos": kp}
        if quantized:
            new_cache["k_exp"] = ek_c
            new_cache["v_exp"] = ev_c

    out = pdot(out.reshape(B, S, cfg.n_heads * cfg.head_dim), params["wo"], mode, wq=params.get("wo_q"))
    return out, new_cache


def _prefill_cache(cache, k, v, positions, window):
    """Populate cache buffers from a prefill segment starting at pos 0.

    Full attention: write k/v at [0:S).  SWA: keep the last ``window``
    tokens, rolled so each lands at slot ``pos % window``.  Quantized
    caches get per-position Q-format exponents.
    """
    B, S = k.shape[0], k.shape[1]
    L = cache["k"].shape[1]
    dt = cache["k"].dtype
    quantized = "k_exp" in cache
    if quantized:
        k, e_k = _q8(k, axes=(3,))                       # exps (B, S, KV)
        v, e_v = _q8(v, axes=(3,))

    def place(buf, seg, fill_dtype):
        if window is None or L >= S:
            return jax.lax.dynamic_update_slice_in_dim(buf, seg.astype(fill_dtype), 0, axis=1)
        return jnp.roll(seg[:, S - L :].astype(fill_dtype), S % L, axis=1)

    out = {
        "k": place(cache["k"], k, dt),
        "v": place(cache["v"], v, dt),
        "pos": place(cache["pos"], positions, jnp.int32),
    }
    if quantized:
        out["k_exp"] = place(cache["k_exp"], e_k, jnp.int32)
        out["v_exp"] = place(cache["v_exp"], e_v, jnp.int32)
    return out


def _store(buf, val, slot):
    """buf (B, L, ...) <- val (B, ...) at per-batch slot (B,)."""
    idx = slot[:, None]  # (B,1)
    oh = jax.nn.one_hot(slot, buf.shape[1], dtype=buf.dtype)  # (B, L)
    oh = oh.reshape(oh.shape + (1,) * (buf.ndim - 2))
    return buf * (1 - oh) + oh * val[:, None]


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / deepseek-family latent attention)
# ---------------------------------------------------------------------------


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_forward(params, x, cfg: ModelConfig, *, positions, mode="precise", cache=None, prefill: bool = False, constrain=lambda x, kind: x):
    B, S, _ = x.shape
    m = cfg.mla
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    h = rms_norm(x, params["norm"], cfg.rms_eps)
    q_lat = rms_norm(pdot(h, params["wq_a"], mode, wq=params.get("wq_a_q")), params["q_norm"], cfg.rms_eps)
    q = pdot(q_lat, params["wq_b"], mode, wq=params.get("wq_b_q")).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv_a = pdot(h, params["wkv_a"], mode, wq=params.get("wkv_a_q"))
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], params["kv_norm"], cfg.rms_eps)
    k_rope = kv_a[..., m.kv_lora_rank :]  # (B,S,rope_d) shared across heads

    sin, cos = rope_tables(positions, rope_d, cfg.rope_base, mode)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0]

    w_b = params["wkv_b"].reshape(m.kv_lora_rank, H, nope + vd)
    w_uk, w_uv = w_b[..., :nope], w_b[..., nope:]

    if cache is None or prefill:
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, w_uk).astype(x.dtype)
        v = jnp.einsum("bsr,rhd->bshd", ckv, w_uv).astype(x.dtype)
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d)).astype(x.dtype)
        k = constrain(jnp.concatenate([k_nope, k_rope_b], axis=-1), "heads4d")
        v = constrain(v, "heads4d")
        q_full = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "heads4d")
        out = chunked_attention(
            q_full, k, v,
            q_positions=positions, causal=True,
        )
        new_cache = None
        if prefill:
            dt = cache["ckv"].dtype
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(dt), 0, axis=1
                ),
                "krope": jax.lax.dynamic_update_slice_in_dim(
                    cache["krope"], k_rope.astype(dt), 0, axis=1
                ),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], positions.astype(jnp.int32), 0, axis=1
                ),
            }
    elif S == 1:
        # decode: absorbed form — score via latent space, cache stays rank-sized
        slot = positions[:, 0] % cache["ckv"].shape[1]
        ckv_c = _store(cache["ckv"], ckv[:, 0], slot)
        kr_c = _store(cache["krope"], k_rope[:, 0], slot)
        kp = _store(cache["pos"], positions[:, 0], slot)
        # q_eff[h] = q_nope[h] @ w_uk[h] : (B,H,rank)
        q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
        s = jnp.einsum("bhr,blr->bhl", q_eff.astype(jnp.float32), ckv_c.astype(jnp.float32))
        s = s + jnp.einsum(
            "bhd,bld->bhl", q_rope[:, 0].astype(jnp.float32), kr_c.astype(jnp.float32)
        )
        s = s / math.sqrt(nope + rope_d)
        valid = (kp[:, None, :] >= 0) & (kp[:, None, :] <= positions[:, 0][:, None, None])
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhl,blr->bhr", p, ckv_c.astype(jnp.float32))  # (B,H,rank)
        out = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
        out = out[:, None].astype(x.dtype)  # (B,1,H,vd)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos": kp}
    else:
        # segment decode: absorbed form with S queries, per-query masks
        L = cache["ckv"].shape[1]
        if S > L:
            raise ValueError(f"segment length {S} exceeds cache length {L}")
        ckv_c, kr_c, kp = cache["ckv"], cache["krope"], cache["pos"]
        for s_i in range(S):
            slot = positions[:, s_i] % L
            ckv_c = _store(ckv_c, ckv[:, s_i], slot)
            kr_c = _store(kr_c, k_rope[:, s_i], slot)
            kp = _store(kp, positions[:, s_i], slot)
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
        s = jnp.einsum("bshr,blr->bshl", q_eff.astype(jnp.float32), ckv_c.astype(jnp.float32))
        s = s + jnp.einsum(
            "bshd,bld->bshl", q_rope.astype(jnp.float32), kr_c.astype(jnp.float32)
        )
        s = s / math.sqrt(nope + rope_d)
        valid = (kp[:, None, None, :] >= 0) & (kp[:, None, None, :] <= positions[:, :, None, None])
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bshl,blr->bshr", p, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv.astype(jnp.float32))
        out = out.astype(x.dtype)  # (B,S,H,vd)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos": kp}

    out = pdot(out.reshape(B, S, H * vd), params["wo"], mode, wq=params.get("wo_q"))
    return out, new_cache
