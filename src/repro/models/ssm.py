"""Mamba-2 (SSD — state-space duality) mixer layer.

Training/prefill uses the chunked dual form: within a chunk the
recurrence is expressed as a masked-attention-like quadratic product;
across chunks a sequential ``lax.scan`` carries the (heads, d_state,
head_dim) state — O(S) total work, O(chunk^2) intra-chunk.

Decode is the pure recurrence: ``h = exp(dt*A) h + dt * B (x)``,
``y = C h + D x`` — one token, no sequence dimension, which is what
makes the 500k-context cell trivially sub-quadratic for this family.

Jamba's mamba layers reuse this block with d_state=16 (noted in
DESIGN.md: Jamba ships Mamba-1 layers; we adapt to the SSD form with
matching state size — same state capacity, TPU-friendlier compute).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec, attn_norm_spec, pdot, rms_norm

__all__ = ["ssm_specs", "ssm_forward", "init_ssm_cache", "reset_ssm_cache_slot"]


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state
    conv_dim = d_in + 2 * gs
    return {
        "norm": attn_norm_spec(d),
        "wz": Spec((d, d_in), ("embed", "ssm")),
        "wx": Spec((d, d_in), ("embed", "ssm")),
        "wB": Spec((d, gs), ("embed", None)),
        "wC": Spec((d, gs), ("embed", None)),
        "wdt": Spec((d, nh), ("embed", None)),
        "conv_w": Spec((s.d_conv, conv_dim), (None, "ssm"), scale=0.5),
        "conv_b": Spec((conv_dim,), ("ssm",), init="zeros"),
        "A_log": Spec((nh,), (None,), init="uniform", scale=1.0),
        "D": Spec((nh,), (None,), init="ones"),
        "dt_bias": Spec((nh,), (None,), init="zeros"),
        "out_norm": Spec((d_in,), ("ssm",), init="zeros"),
        "wo": Spec((d_in, d), ("ssm", "embed")),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gs = s.n_groups * s.d_state
    conv_dim = d_in + 2 * gs
    return {
        "state": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def reset_ssm_cache_slot(cache: dict, slot) -> dict:
    """Zero one batch slot of an SSM cache (continuous-batching
    admission: the recurrent state and conv history of the evicted
    request must not leak into the next occupant).  ``slot`` may be a
    traced int32 — jit-safe."""
    return {
        k: v.at[slot].set(jnp.zeros(v.shape[1:], v.dtype)) for k, v in cache.items()
    }


def _causal_depthwise_conv(x, w, b, carry: Optional[jnp.ndarray] = None):
    """x: (B, S, C) with window w: (K, C).  carry: (B, K-1, C) history
    (decode) or None (train: zero left-pad)."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_carry = xp[:, -(K - 1) :, :] if K > 1 else carry
    return out + b[None, None, :], new_carry


def _ssd_chunked(x, dt, A, B_, C_, chunk: int, initial_state=None):
    """SSD dual form.

    x:  (B, S, nh, hd)   inputs per head
    dt: (B, S, nh)       positive step sizes
    A:  (nh,)            negative decay rates
    B_: (B, S, ds)       input projections (n_groups=1, broadcast to heads)
    C_: (B, S, ds)       output projections
    initial_state: optional (B, nh, ds, hd) carried-in state (mid-sequence
    continuation: speculative verify segments, chunked prefill) — zeros
    when omitted (training / prefill from position 0).
    Returns (y: (B, S, nh, hd), final_state).
    """
    Bb, S, nh, hd = x.shape
    ds = B_.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    # chunked views, scan axis first: (nc, B, chunk, ...)
    xc = x.reshape(Bb, nc, chunk, nh, hd).swapaxes(0, 1)
    dtc = dt.reshape(Bb, nc, chunk, nh).swapaxes(0, 1)
    Bc = B_.reshape(Bb, nc, chunk, ds).swapaxes(0, 1)
    Cc = C_.reshape(Bb, nc, chunk, ds).swapaxes(0, 1)

    def step(state, blk):
        xb, dtb, Bb_, Cb = (v.astype(jnp.float32) for v in blk)
        dA = dtb * A[None, None, :]             # (B,L,nh) negative
        l = jnp.cumsum(dA, axis=1)              # within-chunk log-decay
        # intra-chunk: scores[b,h,i,j] = C_i . B_j * exp(l_i - l_j) * dt_j, j <= i
        logdiff = l[:, :, None, :] - l[:, None, :, :]          # (B,L,L,nh)
        causal = jnp.tril(jnp.ones((logdiff.shape[1], logdiff.shape[1]), bool))
        # mask BEFORE exp: above-diagonal logdiff is positive and can
        # overflow to inf, which would poison gradients through where.
        logdiff = jnp.where(causal[None, :, :, None], logdiff, -jnp.inf)
        decay = jnp.exp(logdiff)
        cb = jnp.einsum("bid,bjd->bij", Cb, Bb_)               # (B,L,L)
        w = cb[..., None] * decay * dtb[:, None, :, :]         # (B,L,L,nh)
        y_intra = jnp.einsum("bijh,bjhd->bihd", w, xb)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bid,bhdp,bih->bihp", Cb, state, jnp.exp(l)
        )
        # state update: decay whole chunk + inject this chunk's inputs
        total = l[:, -1, :]                                    # (B,nh)
        inj = jnp.einsum(
            "bjd,bjhp,bjh->bhdp", Bb_, xb, jnp.exp(total[:, None, :] - l) * dtb
        )
        state = state * jnp.exp(total)[:, :, None, None] + inj
        return state, y_intra + y_inter

    if initial_state is None:
        state0 = jnp.zeros((Bb, nh, ds, hd), jnp.float32)
    else:
        state0 = initial_state.astype(jnp.float32)
    # keep the scanned views in their storage dtype; each step upcasts
    # its own chunk (full-sequence f32 copies were 2x the buffer cost)
    final_state, yc = jax.lax.scan(step, state0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bb, nc * chunk, nh, hd)
    return y[:, :S], final_state


def _ssd_segment(xs, dt, A, Bp, Cp, state0):
    """Sequential recurrence over a short decode segment, emitting the
    state AFTER EVERY position (the rollback candidates for speculative
    verification).  Each scan step performs exactly the einsums of the
    single-token decode branch, so the per-position states match what a
    sequence of single-token decodes would have produced from the same
    layer inputs.

    xs (B,S,nh,hd), dt (B,S,nh), Bp/Cp (B,S,ds), state0 (B,nh,ds,hd)
    -> (y (B,S,nh,hd) f32, states (B,S,nh,ds,hd) f32)
    """

    def step(state, blk):
        xb, dtb, Bb_, Cb = blk
        dA = jnp.exp(dtb * A[None, :])                               # (B,nh)
        inj = jnp.einsum("bd,bhp,bh->bhdp", Bb_, xb, dtb)
        state = state * dA[:, :, None, None] + inj
        y = jnp.einsum("bd,bhdp->bhp", Cb, state)
        return state, (y, state)

    _, (ys, states) = jax.lax.scan(
        step,
        state0,
        (xs.swapaxes(0, 1), dt.swapaxes(0, 1), Bp.swapaxes(0, 1), Cp.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), states.swapaxes(0, 1)


def ssm_forward(
    params,
    x,
    cfg: ModelConfig,
    *,
    mode: str = "precise",
    cache: Optional[dict] = None,
    prefill: bool = False,
    constrain=lambda x, kind: x,
    seg_aux: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d). cache given + prefill -> populate state from the
    segment; cache given, S==1 -> single-step recurrence decode;
    cache given, S>1, not prefill -> mid-sequence SEGMENT decode (the
    speculative-verify / chunked-continuation path): the recurrence
    continues from the cached state, and — because the SSM state is
    cumulative rather than position-indexed — ``seg_aux`` (a dict the
    caller owns) receives the per-position rollback candidates:
    ``states`` (B,S,nh,ds,hd) and ``conv_hist`` (B,K-1+S,conv_dim)."""
    B, S, d = x.shape
    s = cfg.ssm
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state

    h = rms_norm(x, params["norm"], cfg.rms_eps)
    z = pdot(h, params["wz"], mode, wq=params.get("wz_q"))
    xs = pdot(h, params["wx"], mode, wq=params.get("wx_q"))
    Bp = pdot(h, params["wB"], mode, wq=params.get("wB_q"))
    Cp = pdot(h, params["wC"], mode, wq=params.get("wC_q"))
    dt_raw = pdot(h, params["wdt"], mode, wq=params.get("wdt_q"))

    conv_in = jnp.concatenate([xs, Bp, Cp], axis=-1)
    conv_out, new_conv = _causal_depthwise_conv(
        conv_in, params["conv_w"], params["conv_b"],
        carry=None if (cache is None or prefill) else cache["conv"],
    )
    # silu in f32, stored bf16: at S=32k the (B, S, conv_dim) buffers
    # are GiB-scale per mamba layer (7/period for jamba) — §Perf P6.
    # "exact" (serving) skips the bf16 round-trip so decode's conv
    # output is bit-aligned with prefill's (decode S=1 buffers are tiny).
    conv_dt = jnp.float32 if mode == "exact" else jnp.bfloat16
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(conv_dt)
    xs = constrain(conv_out[..., :d_in].reshape(B, S, nh, s.head_dim), "heads4d")
    Bp = conv_out[..., d_in : d_in + gs]
    Cp = conv_out[..., d_in + gs :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if cache is None or prefill:
        y, final_state = _ssd_chunked(xs, dt, A, Bp, Cp, chunk=s.chunk)
        new_cache = None
        if prefill:
            new_cache = {
                "state": final_state.astype(cache["state"].dtype),
                "conv": new_conv.astype(cache["conv"].dtype),
            }
    elif S == 1:
        state = cache["state"]
        dA = jnp.exp(dt[:, 0, :] * A[None, :])                       # (B,nh)
        inj = jnp.einsum("bd,bhp,bh->bhdp", Bp[:, 0], xs[:, 0], dt[:, 0])
        state = state * dA[:, :, None, None] + inj
        y = jnp.einsum("bd,bhdp->bhp", Cp[:, 0], state)[:, None]     # (B,1,nh,hd)
        new_cache = {"state": state, "conv": new_conv}
    else:
        # mid-sequence segment decode (speculative verify): sequential
        # recurrence from the cached state, per-position states kept
        # as rollback candidates
        y, states = _ssd_segment(
            xs.astype(jnp.float32), dt, A,
            Bp.astype(jnp.float32), Cp.astype(jnp.float32),
            cache["state"].astype(jnp.float32),
        )
        if seg_aux is not None:
            seg_aux["states"] = states
            # the conv input window history: carry ++ this segment's
            # conv inputs — the carry after accepting ``a`` tokens is
            # rows [a : a+K-1]
            seg_aux["conv_hist"] = jnp.concatenate(
                [cache["conv"].astype(conv_in.dtype), conv_in], axis=1
            )
        new_cache = {"state": states[:, -1], "conv": new_conv}

    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["out_norm"], cfg.rms_eps)
    out = pdot(y, params["wo"], mode, wq=params.get("wo_q"))
    return out, new_cache
