"""Pure-JAX decoder LM zoo: GQA / SWA / local-global / MLA attention,
capacity-dispatch MoE, Mamba-2 SSD, hybrid periods — all composed by
transformer.py and assembled by model.py, with the paper's precision
modes dispatched per-op (layers.pdot / layers.rope_tables)."""

from repro.models.config import (
    LayerSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    smoke_config,
)
from repro.models.model import (
    cache_layout,
    commit_segment,
    decode_step,
    init_caches,
    init_params,
    param_specs,
    prefill_step,
    reset_cache_slot,
    segment_step,
    train_loss,
    truncate_cache_slot,
    write_cache_slot,
)
