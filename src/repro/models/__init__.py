"""Pure-JAX decoder LM zoo: GQA / SWA / local-global / MLA attention,
capacity-dispatch MoE, Mamba-2 SSD, hybrid periods — all composed by
transformer.py and assembled by model.py, with the paper's precision
modes dispatched per-op (layers.pdot / layers.rope_tables)."""

from repro.models.config import (
    LayerSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    smoke_config,
)
from repro.models.model import (
    decode_step,
    init_caches,
    init_params,
    param_specs,
    prefill_step,
    reset_cache_slot,
    train_loss,
    write_cache_slot,
)
