"""JAX version compatibility shims.

The repo targets the current JAX API surface but must also run on
0.4.x-era releases (the pinned CI/container toolchain).  Everything
version-sensitive is funneled through here:

* ``CompilerParams`` — ``pltpu.TPUCompilerParams`` was renamed to
  ``pltpu.CompilerParams``.
* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, with ``check_rep`` renamed to ``check_vma``.
* ``default_interpret`` — backend-dependent Pallas interpret default,
  so kernel call sites never hardcode ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu

__all__ = ["CompilerParams", "shard_map", "default_interpret"]

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled kernels on TPU backends,
    interpreter everywhere else (CPU CI, GPU dry-runs).

    Every kernel entrypoint takes ``interpret=None`` and resolves it
    here, so real hardware runs compiled Mosaic kernels without any
    call-site changes.  Cached: the backend cannot change mid-process.
    """
    return jax.default_backend() != "tpu"


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, **kw,
        )
