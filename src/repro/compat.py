"""JAX version compatibility shims.

The repo targets the current JAX API surface but must also run on
0.4.x-era releases (the pinned CI/container toolchain).  Everything
version-sensitive is funneled through here:

* ``CompilerParams`` — ``pltpu.TPUCompilerParams`` was renamed to
  ``pltpu.CompilerParams``.
* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, with ``check_rep`` renamed to ``check_vma``.
"""

from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

__all__ = ["CompilerParams", "shard_map"]

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, **kw,
        )
