"""Pallas TPU kernel: fused FAST-path SwiGLU with ONE deferred correction.

The paper's C3 kernel defers its correction so each output element sees
one rounding event (Eq. 18).  The model-layer FAST path used to undo
that win a layer up: ``swiglu_mlp`` ran three independent
quantize -> int8-dot -> rescale round trips plus a separate CORDIC
activation dispatch, bouncing the gate activation through HBM and f32
between every stage.  This kernel applies the same "keep intermediates
in fast memory, correct once" principle to the whole hidden stage:

* one streamed ``x`` tile feeds BOTH int8xint8 MXU accumulations
  (``x @ Wg`` and ``x @ Wu``) — the activations are quantized once,
  not once per matmul;
* the CORDIC ``sigmoid_q16_body`` (core/cordic, Walther hyperbolic
  mode) is applied to the gate accumulator *inside* the kernel, in
  Q16.16, straight off the VMEM scratch — the pre-activation never
  round-trips through HBM or f32;
* the epilogue applies ONE combined power-of-two correction:
  ``out = acc_g * acc_u * sigmoid(g_q16) * 2**(e_g + e_u - 16)``.
  Both ``exp2`` factors are exact; the only rounding events per output
  element are the single deferred shift of the gate into Q16.16 (the
  sigmoid operand) and the final f32 mantissa round.

K-budget note — the ``@ Wd`` down-projection is NOT fused: contracting
over d_ff needs the full activation row resident, and at the assigned
shapes (gemma2 d_ff=9216, mixtral expert 16384) a ``(bm, d_ff)`` f32
row tile alone exceeds the VMEM budget that double-buffering leaves.
The wired model path instead quantizes the activation once and runs the
down-projection through the cached-weight int8 path (one more deferred
correction — two per layer total vs. three plus an activation bounce).

Grid: ``(M/bm, F/bn, K/bk)`` with K innermost ("arbitrary" semantics);
the two int32 accumulators live in VMEM scratch persisting across the K
steps of one (i, j) tile, exactly like kernels/qmatmul.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import CompilerParams, default_interpret
from repro.core.cordic import sigmoid_q16_body

__all__ = [
    "swiglu_body_q16",
    "fused_swiglu_kernel_call",
    "DEFAULT_BM",
    "DEFAULT_BN",
    "DEFAULT_BK",
]

# (bm*bk + 2*bk*bn) int8 + 2*bm*bn int32 acc + bm*bn f32 out ~= 1.1 MiB
# single-buffered — well under VMEM with the Pallas pipeline's x2.
DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512

_RAW_MAX = (1 << 31) - 1


def swiglu_body_q16(acc_g, acc_u, e_g, e_u, *, return_parts: bool = False):
    """The shared element contract of the fused epilogue.

    ``acc_g``/``acc_u``: exact int32 MXU accumulators of the int8 gate /
    up products; ``e_g``/``e_u``: combined power-of-two exponents
    (activation + per-channel weight), broadcastable against the
    accumulators.  Three steps, fixed order (the oracle in ref.py and
    the XLA form in ops.py replay exactly this):

    1. deferred shift of ``acc_g`` into Q16.16 — saturating on the
       left-shift side (sigmoid is flat there anyway), round-half-up on
       the right-shift side: the single integer rounding event;
    2. ``sigmoid_q16_body`` on the Q16.16 gate (integer shift-add);
    3. one combined correction in f32:
       ``(acc_g * acc_u) * sig * 2**(e_g + e_u - 16)`` — both scales
       exact powers of two, silu(g) = g * sigmoid(g) recovered from the
       RAW accumulator so step 1's quantization only touches the
       sigmoid operand.
    """
    acc_g = jnp.asarray(acc_g, jnp.int32)
    acc_u = jnp.asarray(acc_u, jnp.int32)
    e_g = jnp.asarray(e_g, jnp.int32)
    e_u = jnp.asarray(e_u, jnp.int32)

    s = e_g + 16
    sr = jnp.minimum(jnp.maximum(-s, 0), 31)
    sl = jnp.minimum(jnp.maximum(s, 0), 31)
    half = jnp.where(sr > 0, jnp.int32(1) << jnp.maximum(sr - 1, 0), 0)
    shifted_r = (acc_g + half) >> sr
    lim = jnp.int32(_RAW_MAX) >> sl
    shifted_l = jnp.where(
        acc_g > lim,
        jnp.int32(_RAW_MAX),
        jnp.where(acc_g < -lim, jnp.int32(-_RAW_MAX), acc_g << sl),
    )
    gate_q16 = jnp.where(s >= 0, shifted_l, shifted_r)

    sig = sigmoid_q16_body(gate_q16)

    comb = jnp.exp2((e_g + e_u - 16).astype(jnp.float32))
    out = (
        acc_g.astype(jnp.float32) * acc_u.astype(jnp.float32)
    ) * sig.astype(jnp.float32) * comb
    if return_parts:
        return out, gate_q16, sig
    return out


def _kernel(x_ref, wg_ref, wu_ref, ea_ref, eg_ref, eu_ref, out_ref,
            accg_ref, accu_ref, *, nk: int):
    """One (i, j, k) grid step.

    x_ref:  (bm, bk) int8      activation tile (shared by both matmuls)
    wg_ref: (bk, bn) int8      gate-weight tile
    wu_ref: (bk, bn) int8      up-weight tile
    ea_ref: (1, 1)   int32     activation exponent (per-tensor)
    eg_ref: (1, bn)  int32     gate-weight exponents (per-channel)
    eu_ref: (1, bn)  int32     up-weight exponents (per-channel)
    out_ref:(bm, bn) f32       silu(x@Wg) * (x@Wu) tile
    accg_ref/accu_ref: (bm, bn) int32 VMEM scratch accumulators
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    dims = (((1,), (0,)), ((), ()))
    accg_ref[...] += jax.lax.dot_general(
        x, wg_ref[...], dimension_numbers=dims, preferred_element_type=jnp.int32
    )
    accu_ref[...] += jax.lax.dot_general(
        x, wu_ref[...], dimension_numbers=dims, preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        e_g = (ea_ref[0, 0] + eg_ref[0, :])[None, :]
        e_u = (ea_ref[0, 0] + eu_ref[0, :])[None, :]
        out_ref[...] = swiglu_body_q16(accg_ref[...], accu_ref[...], e_g, e_u)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def fused_swiglu_kernel_call(
    x_q,
    wg_q,
    wu_q,
    ea,
    eg,
    eu,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: Optional[bool] = None,
):
    """Invoke the fused kernel on padded int8 operands.

    x_q: (M, K) int8;  wg_q/wu_q: (K, F) int8
    ea:  () or (1,1) int32 per-tensor activation exponent
    eg/eu: (F,) int32 per-channel weight exponents
    Returns (M, F) float32 ``silu(x@Wg) * (x@Wu)``.

    Zero padding is total for the body: padded accumulators are 0, the
    up factor is 0, so padded outputs are exactly 0 and sliced away.
    """
    if interpret is None:
        interpret = default_interpret()
    M, K = x_q.shape
    K2, F = wg_q.shape
    assert K == K2 and wu_q.shape == wg_q.shape, (x_q.shape, wg_q.shape, wu_q.shape)
    bm_, bn_, bk_ = min(bm, _rup(M, 8)), min(bn, _rup(F, 128)), min(bk, _rup(K, 128))

    Mp, Fp, Kp = _rup(M, bm_), _rup(F, bn_), _rup(K, bk_)
    x_p = jnp.pad(x_q, ((0, Mp - M), (0, Kp - K)))
    wg_p = jnp.pad(wg_q, ((0, Kp - K), (0, Fp - F)))
    wu_p = jnp.pad(wu_q, ((0, Kp - K), (0, Fp - F)))
    eg_p = jnp.pad(jnp.asarray(eg, jnp.int32).reshape(1, F), ((0, 0), (0, Fp - F)))
    eu_p = jnp.pad(jnp.asarray(eu, jnp.int32).reshape(1, F), ((0, 0), (0, Fp - F)))
    ea_ = jnp.asarray(ea, jnp.int32).reshape(1, 1)

    nk = Kp // bk_
    grid = (Mp // bm_, Fp // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Fp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm_, bn_), jnp.int32),
            pltpu.VMEM((bm_, bn_), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_p, wg_p, wu_p, ea_, eg_p, eu_p)
    return out[:M, :F]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
