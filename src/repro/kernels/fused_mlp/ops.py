"""Public ops for the fused FAST-path SwiGLU kernel.

``fused_swiglu``        — float in / float out hidden stage through the
                          Pallas kernel (quantize x once -> fused gate+up
                          int8 MXU -> in-kernel CORDIC sigmoid -> one
                          combined correction).
``fused_swiglu_xla``    — the kernel-equivalent XLA form on pre-quantized
                          operands: ``lax.dot_general`` int8 accumulation
                          plus the SAME ``swiglu_body_q16`` epilogue.
                          Lowers on every backend; it is what
                          ``models/layers.py`` wires into the model FAST
                          path (mirroring ``dot_fast_int8`` vs qmatmul).
``fused_swiglu_parts``  — XLA form returning the integer intermediates
                          (gate Q16.16, sigmoid) so tests can pin the
                          shared body contract bit-exactly against the
                          int64 oracle in ref.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize_pow2
from repro.kernels.fused_mlp.fused_mlp import (
    fused_swiglu_kernel_call,
    swiglu_body_q16,
)

__all__ = ["fused_swiglu", "fused_swiglu_xla", "fused_swiglu_parts"]


def _acc_pair(x_q, wg_q, wu_q):
    dims = (((x_q.ndim - 1,), (0,)), ((), ()))
    acc_g = jax.lax.dot_general(
        x_q, wg_q, dimension_numbers=dims, preferred_element_type=jnp.int32
    )
    acc_u = jax.lax.dot_general(
        x_q, wu_q, dimension_numbers=dims, preferred_element_type=jnp.int32
    )
    return acc_g, acc_u


@jax.jit
def fused_swiglu_xla(x_q, wg_q, wu_q, ea, eg, eu):
    """Kernel-equivalent XLA form on int8 operands: (…, K) x (K, F) x 2
    -> (…, F) f32 ``silu(x@Wg) * (x@Wu)`` with the shared epilogue."""
    acc_g, acc_u = _acc_pair(x_q, wg_q, wu_q)
    ea = jnp.asarray(ea, jnp.int32)
    e_g = ea + jnp.asarray(eg, jnp.int32).reshape(-1)
    e_u = ea + jnp.asarray(eu, jnp.int32).reshape(-1)
    return swiglu_body_q16(acc_g, acc_u, e_g, e_u)


@jax.jit
def fused_swiglu_parts(x_q, wg_q, wu_q, ea, eg, eu):
    """XLA form returning ``(out, gate_q16, sigmoid_q16)`` — the full
    shared-body contract, for bit-exact oracle comparison."""
    acc_g, acc_u = _acc_pair(x_q, wg_q, wu_q)
    ea = jnp.asarray(ea, jnp.int32)
    e_g = ea + jnp.asarray(eg, jnp.int32).reshape(-1)
    e_u = ea + jnp.asarray(eu, jnp.int32).reshape(-1)
    return swiglu_body_q16(acc_g, acc_u, e_g, e_u, return_parts=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_swiglu(x, wg, wu, interpret: Optional[bool] = None):
    """float (M, K) x (K, F) x 2 -> float32 (M, F) hidden stage via the
    Pallas kernel: x quantized ONCE (per-tensor), weights per-channel."""
    xq = quantize_pow2(x, bits=8, axis=None)
    gq = quantize_pow2(wg, bits=8, axis=1)
    uq = quantize_pow2(wu, bits=8, axis=1)
    return fused_swiglu_kernel_call(
        xq.q, gq.q, uq.q, xq.exp, gq.exp.reshape(-1), uq.exp.reshape(-1),
        interpret=interpret,
    )
