"""Pure NumPy-int64 oracle for the fused SwiGLU kernel.

Pins the shared body contract (fused_mlp.swiglu_body_q16) down to the
bit on the integer stages and to float64 on the combined correction:

1. exact int64 accumulation of both int8 matmuls (int32-safe asserted);
2. deferred saturating round-shift of the gate accumulator to Q16.16 —
   the single integer rounding event;
3. ``sigmoid_ref`` (the NumPy universal-CORDIC oracle) on the Q16.16
   gate;
4. one combined power-of-two correction
   ``acc_g * acc_u * sig * 2**(e_g + e_u - 16)`` in float64 (the kernel
   computes it in f32 — compare with rtol ~1e-5, the f32 mantissa).
"""

from __future__ import annotations

import numpy as np

from repro.kernels.cordic.ref import sigmoid_ref

_RAW_MAX = (1 << 31) - 1


def swiglu_body_ref(acc_g, acc_u, e_g, e_u, return_parts: bool = False):
    """NumPy mirror of ``fused_mlp.swiglu_body_q16`` on int64 inputs."""
    acc_g = np.asarray(acc_g, np.int64)
    acc_u = np.asarray(acc_u, np.int64)
    e_g = np.asarray(e_g, np.int64)
    e_u = np.asarray(e_u, np.int64)

    s = e_g + 16
    sr = np.minimum(np.maximum(-s, 0), 31)
    sl = np.minimum(np.maximum(s, 0), 31)
    half = np.where(sr > 0, np.int64(1) << np.maximum(sr - 1, 0), 0)
    shifted_r = (acc_g + half) >> sr
    lim = np.int64(_RAW_MAX) >> sl
    shifted_l = np.where(
        acc_g > lim, _RAW_MAX, np.where(acc_g < -lim, -_RAW_MAX, acc_g << sl)
    )
    gate_q16 = np.where(s >= 0, shifted_l, shifted_r).astype(np.int32)

    sig = sigmoid_ref(gate_q16)

    out = (
        acc_g.astype(np.float64)
        * acc_u.astype(np.float64)
        * sig.astype(np.float64)
        * np.exp2((e_g + e_u - 16).astype(np.float64))
    )
    if return_parts:
        return out, gate_q16, sig
    return out


def fused_swiglu_ref(x_q, wg_q, wu_q, ea, eg, eu, return_parts: bool = False):
    """x_q (M,K) int8, wg_q/wu_q (K,F) int8, ea scalar int, eg/eu (F,) int.

    Returns float64 (M, F) — or ``(out, gate_q16, sig)`` with
    ``return_parts`` for the bit-exact intermediate checks.
    """
    x = np.asarray(x_q, np.int64)
    acc_g = x @ np.asarray(wg_q, np.int64)
    acc_u = x @ np.asarray(wu_q, np.int64)
    assert np.all(np.abs(acc_g) < 2**31), "gate accumulation must fit int32"
    assert np.all(np.abs(acc_u) < 2**31), "up accumulation must fit int32"
    e_g = int(ea) + np.asarray(eg, np.int64)[None, :]
    e_u = int(ea) + np.asarray(eu, np.int64)[None, :]
    return swiglu_body_ref(acc_g, acc_u, e_g, e_u, return_parts=return_parts)
