"""Pallas TPU kernels for the paper's compute hot-spots.

qmatmul/   — C3: tiled int8 matmul with deferred power-of-two rescale
cordic/    — C2: 16-iteration shift-add sincos on VPU blocks
flashattn/ — C3's tiling discipline applied to attention: fused
             online-softmax forward (the named remedy for the dominant
             memory term measured in EXPERIMENTS.md §Roofline)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper), ref.py (NumPy-int64 oracle). Validated in
tests/test_kernel_*.py with interpret=True shape/dtype sweeps.
"""
