"""Pure NumPy-int64 oracles for the CORDIC Pallas kernels — bit-exact
contracts (same range reductions, folds, shift-add recurrences).

``cordic_sincos_ref`` pins the circular-rotation kernel; the
``*_ref`` universal ops below pin ``kernels/cordic/universal.py`` and
``repro.core.cordic``'s universal bodies.  Every intermediate stays in
int32 range by construction, so int64 arithmetic here equals the
paired-limb int32 datapath bit for bit.
"""

from __future__ import annotations

import numpy as np

from repro.core.cordic import (
    EXP_FLUSH_LO_Q16,
    EXP_SAT_HI_Q16,
    HALF_PI_Q16,
    HYPER_STAGES,
    INV_LN2_Q16,
    LN2_Q16,
    PI_Q16,
    TWO_PI_Q16,
    angle_consts,
    atan_table,
    atanh_table,
    gain_inverse,
    hyper_gain_inverse,
    hyperbolic_schedule,
)

_ONE = 1 << 16
_HFRAC = 29
_RAW_MAX = (1 << 31) - 1
_RAW_MIN = -(1 << 31)


def cordic_sincos_ref(theta_q, iterations: int = 16, frac_bits: int = 16):
    """theta_q: int32 array (any shape) in Q(m.n). Returns (sin_q, cos_q)."""
    table = atan_table(iterations, frac_bits).astype(np.int64)
    k_inv = np.int64(gain_inverse(iterations, frac_bits))
    pi_q, half_pi_q, two_pi_q = angle_consts(frac_bits)

    t = np.asarray(theta_q, np.int64)
    # floor-mod like jnp — but through int32 wrap-around at the +pi bias,
    # matching the device datapath exactly
    biased = ((t + pi_q + 2**31) % 2**32) - 2**31
    r = np.remainder(biased, two_pi_q) - pi_q
    hi = r > half_pi_q
    lo = r < -half_pi_q
    z = np.where(hi, r - pi_q, np.where(lo, r + pi_q, r))
    negate = hi | lo

    x = np.full_like(z, k_inv)
    y = np.zeros_like(z)
    for i in range(iterations):
        d_pos = z >= 0
        xs = x >> i  # int64 arithmetic shift == int32 asr for in-range values
        ys = y >> i
        x, y, z = (
            np.where(d_pos, x - ys, x + ys),
            np.where(d_pos, y + xs, y - xs),
            np.where(d_pos, z - table[i], z + table[i]),
        )

    cos_q = np.where(negate, -x, x)
    sin_q = np.where(negate, -y, y)
    return sin_q.astype(np.int32), cos_q.astype(np.int32)


# ---------------------------------------------------------------------------
# universal CORDIC oracles (mirror repro.core.cordic bodies, int64)
# ---------------------------------------------------------------------------


def _clamp_raw(v):
    return np.maximum(np.asarray(v, np.int64), _RAW_MIN + 1)


def _ilog2(v):
    v = np.asarray(v, np.int64).copy()
    n = np.zeros_like(v)
    for s in (16, 8, 4, 2, 1):
        gt = v >= (1 << s)
        n = n + np.where(gt, s, 0)
        v = np.where(gt, v >> s, v)
    return n


def _shift_signed(v, s):
    return (v >> np.maximum(s, 0)) << np.maximum(-s, 0)


def _round_shift_right(v, s):
    half = np.where(s > 0, np.int64(1) << np.maximum(s - 1, 0), 0)
    return (v + half) >> s


def _hyper_vectoring(x, y, z, stages):
    sched = hyperbolic_schedule(stages)
    table = atanh_table(sched, _HFRAC)
    for j, i in enumerate(sched):
        neg = y < 0
        xs = x >> i
        ys = y >> i
        t = int(table[j])
        x, y, z = (
            np.where(neg, x + ys, x - ys),
            np.where(neg, y + xs, y - xs),
            np.where(neg, z - t, z + t),
        )
    return x, y, z


def _hyper_rotation(x, y, z, stages):
    sched = hyperbolic_schedule(stages)
    table = atanh_table(sched, _HFRAC)
    for j, i in enumerate(sched):
        pos = z >= 0
        xs = x >> i
        ys = y >> i
        t = int(table[j])
        x, y, z = (
            np.where(pos, x + ys, x - ys),
            np.where(pos, y + xs, y - xs),
            np.where(pos, z - t, z + t),
        )
    return x, y, z


def _linear_div_q16(num, den, iterations=17):
    num = np.asarray(num, np.int64)
    den = np.asarray(den, np.int64)
    s = _HFRAC - _ilog2(np.maximum(den, 1))
    x = _shift_signed(den, -s)
    y = _shift_signed(num, -s)
    z = np.zeros_like(x)
    for i in range(iterations):
        pos = y >= 0
        xs = x >> i
        t = _ONE >> i
        y = np.where(pos, y - xs, y + xs)
        z = np.where(pos, z + t, z - t)
    return z


def div_ref(num_q, den_q, iterations=17):
    """Full-range linear-vectoring division oracle (mirrors
    ``repro.core.cordic.div_q16_body`` in int64)."""
    num = _clamp_raw(num_q)
    den = _clamp_raw(den_q)
    an = np.abs(num)
    ad = np.abs(den)
    bn = _ilog2(np.maximum(an, 1))
    bd = _ilog2(np.maximum(ad, 1))
    nn = _shift_signed(an, bn - _HFRAC)
    dd = _shift_signed(ad, bd - _HFRAC)
    z = _linear_div_q16(nn, np.maximum(dd, 1), iterations)
    e = bn - bd
    zr = _round_shift_right(z, np.maximum(-e, 0))
    sl = np.maximum(e, 0)
    fits = zr <= (_RAW_MAX >> sl)
    mag = np.where(fits, zr << sl, _RAW_MAX)
    out = np.where((num < 0) != (den < 0), -mag, mag)
    sat = np.where(num > 0, _RAW_MAX, _RAW_MIN + 1)
    return np.where(
        np.asarray(den_q, np.int64) == 0, np.where(num == 0, 0, sat), out
    ).astype(np.int32)


def atan2_ref(y_q, x_q, iterations=16, frac_bits=16):
    y0 = _clamp_raw(y_q)
    x0 = _clamp_raw(x_q)
    table = atan_table(iterations, frac_bits)
    pi_q = angle_consts(frac_bits)[0]

    neg_x = x0 < 0
    x1 = np.where(neg_x, -x0, x0)
    y1 = np.where(neg_x, -y0, y0)

    m = np.maximum(np.abs(x1), np.abs(y1))
    s = 28 - _ilog2(np.maximum(m, 1))
    x1 = _shift_signed(x1, -s)
    y1 = _shift_signed(y1, -s)

    z = np.zeros_like(x1)
    for i in range(iterations):
        neg = y1 < 0
        xs = x1 >> i
        ys = y1 >> i
        t = int(table[i])
        x1, y1, z = (
            np.where(neg, x1 - ys, x1 + ys),
            np.where(neg, y1 + xs, y1 - xs),
            np.where(neg, z - t, z + t),
        )

    half_turn = np.where(y0 < 0, -pi_q, pi_q)
    out = np.where(neg_x, z + half_turn, z)
    return np.where((x0 == 0) & (y0 == 0), 0, out).astype(np.int32)


def sqrt_ref(w_q, stages=HYPER_STAGES):
    w = _clamp_raw(w_q)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    b = _ilog2(np.maximum(w, 1))
    s0 = b - 16
    s = np.where((s0 & 1) == 0, s0, s0 + 1)
    u = _shift_signed(w, s)
    u29 = u << (_HFRAC - 16)
    quarter = 1 << (_HFRAC - 2)

    x, _, _ = _hyper_vectoring(u29 + quarter, u29 - quarter, np.zeros_like(u29), stages)
    r29 = (x * k_h_inv + (1 << (_HFRAC - 1))) >> _HFRAC  # q_mul, round-to-nearest
    out = _round_shift_right(r29, (_HFRAC - 16) - (s >> 1))
    return np.where(w <= 0, 0, out).astype(np.int32)


def exp_ref(t_q, stages=HYPER_STAGES):
    t = np.asarray(t_q, np.int64)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    tc = np.clip(t, EXP_FLUSH_LO_Q16 - _ONE, EXP_SAT_HI_Q16 + _ONE)
    k = (((tc * INV_LN2_Q16 + (1 << 15)) >> 16) + (1 << 15)) >> 16
    r = tc - k * LN2_Q16

    x, y, _ = _hyper_rotation(
        np.full_like(t, k_h_inv), np.zeros_like(t), r << (_HFRAC - 16), stages
    )
    er = x + y

    sh = (_HFRAC - 16) - k
    rs = _round_shift_right(er, np.maximum(sh, 0))
    sl = np.maximum(-sh, 0)
    fits = rs <= (_RAW_MAX >> sl)
    out = np.where(fits, rs << sl, _RAW_MAX)
    out = np.where(t >= EXP_SAT_HI_Q16, _RAW_MAX, out)
    return np.where(t <= EXP_FLUSH_LO_Q16, 0, out).astype(np.int32)


def log_ref(w_q, stages=HYPER_STAGES):
    w = _clamp_raw(w_q)
    b = _ilog2(np.maximum(w, 1))
    k = b - 16
    u = _shift_signed(w, k)
    u29 = u << (_HFRAC - 16)
    one29 = 1 << _HFRAC

    _, _, z = _hyper_vectoring(u29 + one29, u29 - one29, np.zeros_like(u29), stages)
    lnu = (z + (1 << (_HFRAC - 18))) >> (_HFRAC - 17)
    return np.where(w <= 0, _RAW_MIN, lnu + k * LN2_Q16).astype(np.int32)


def tanh_ref(t_q, stages=HYPER_STAGES):
    t = _clamp_raw(t_q)
    at = np.abs(t)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    ts = np.minimum(at, _ONE)
    x, y, _ = _hyper_rotation(
        np.full_like(t, k_h_inv), np.zeros_like(t), ts << (_HFRAC - 16), stages
    )
    near = _linear_div_q16(y >> (_HFRAC - 16), np.maximum(x >> (_HFRAC - 16), 1))

    a2 = np.minimum(at, -EXP_FLUSH_LO_Q16)
    e = exp_ref(-(a2 << 1), stages).astype(np.int64)
    far = _linear_div_q16(_ONE - e, _ONE + e)

    mag = np.minimum(np.where(at <= _ONE, near, far), _ONE)
    return np.where(t < 0, -mag, mag).astype(np.int32)


def sigmoid_ref(t_q, stages=HYPER_STAGES):
    t = _clamp_raw(t_q)
    th = tanh_ref(t >> 1, stages).astype(np.int64)
    return ((th + _ONE + 1) >> 1).astype(np.int32)


UNARY_REFS = {
    "sqrt": sqrt_ref,
    "exp": exp_ref,
    "log": log_ref,
    "tanh": tanh_ref,
    "sigmoid": sigmoid_ref,
}
