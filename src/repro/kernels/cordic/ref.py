"""Pure NumPy-int64 oracle for the CORDIC Pallas kernel — bit-exact
contract (same range reduction, fold, shift-add recurrence)."""

from __future__ import annotations

import numpy as np

from repro.core.cordic import HALF_PI_Q16, PI_Q16, TWO_PI_Q16, atan_table, gain_inverse


def cordic_sincos_ref(theta_q, iterations: int = 16):
    """theta_q: int32 array (any shape) in Q16.16. Returns (sin_q, cos_q)."""
    table = atan_table(iterations).astype(np.int64)
    k_inv = np.int64(gain_inverse(iterations))

    t = np.asarray(theta_q, np.int64)
    r = np.remainder(t + PI_Q16, TWO_PI_Q16) - PI_Q16  # floor-mod, like jnp
    hi = r > HALF_PI_Q16
    lo = r < -HALF_PI_Q16
    z = np.where(hi, r - PI_Q16, np.where(lo, r + PI_Q16, r))
    negate = hi | lo

    x = np.full_like(z, k_inv)
    y = np.zeros_like(z)
    for i in range(iterations):
        d_pos = z >= 0
        xs = x >> i  # int64 arithmetic shift == int32 asr for in-range values
        ys = y >> i
        x, y, z = (
            np.where(d_pos, x - ys, x + ys),
            np.where(d_pos, y + xs, y - xs),
            np.where(d_pos, z - table[i], z + table[i]),
        )

    cos_q = np.where(negate, -x, x)
    sin_q = np.where(negate, -y, y)
    return sin_q.astype(np.int32), cos_q.astype(np.int32)
