"""Pallas TPU kernel: 16-iteration CORDIC sincos on int32 blocks.

The paper's C2 on the vector unit: each grid step loads a (rows, 128)
block of Q16.16 angles into VMEM and runs the fully-unrolled shift-add
iteration on the VPU — integer adds, arithmetic shifts and selects
only, exactly the instruction mix the paper uses on the Xtensa integer
pipeline.  The quadrant normalization is branchless (selects), which is
the paper's §8.2 future-work item and is *free* on a SIMD datapath.

The atan table is baked into the kernel as immediates (64 bytes of
constants — the paper's §4.3.2 footprint), not streamed from HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core.cordic import (
    HALF_PI_Q16,
    PI_Q16,
    TWO_PI_Q16,
    atan_table,
    gain_inverse,
)
from repro.compat import CompilerParams, default_interpret

__all__ = ["cordic_kernel_call", "LANE", "DEFAULT_BLOCK_ROWS"]

LANE = 128               # TPU lane width: minor dim of every block
DEFAULT_BLOCK_ROWS = 256  # (256, 128) int32 x 3 live arrays ~= 384 KiB VMEM


def _kernel(theta_ref, sin_ref, cos_ref, *, iterations: int):
    table = [int(v) for v in atan_table(iterations)]
    k_inv = gain_inverse(iterations)

    theta = theta_ref[...]
    # branchless range reduction to [-pi, pi), then fold to [-pi/2, pi/2]
    r = jnp.remainder(theta + PI_Q16, TWO_PI_Q16) - PI_Q16
    hi = r > HALF_PI_Q16
    lo = r < -HALF_PI_Q16
    z = jnp.where(hi, r - PI_Q16, jnp.where(lo, r + PI_Q16, r))
    negate = hi | lo

    x = jnp.full_like(theta, k_inv)
    y = jnp.zeros_like(theta)
    for i in range(iterations):  # static unroll (paper relies on -O2)
        d_pos = z >= 0
        xs = x >> i
        ys = y >> i
        x, y, z = (
            jnp.where(d_pos, x - ys, x + ys),
            jnp.where(d_pos, y + xs, y - xs),
            jnp.where(d_pos, z - table[i], z + table[i]),
        )

    cos_ref[...] = jnp.where(negate, -x, x)
    sin_ref[...] = jnp.where(negate, -y, y)


@functools.partial(jax.jit, static_argnames=("iterations", "block_rows", "interpret"))
def cordic_kernel_call(
    theta_q,
    *,
    iterations: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
):
    """sin/cos of a Q16.16 int32 array of any shape.

    Flattens to (rows, 128) blocks; pads the tail; restores the shape.
    """
    if interpret is None:
        interpret = default_interpret()
    shape = theta_q.shape
    flat = jnp.ravel(jnp.asarray(theta_q, jnp.int32))
    n = flat.shape[0]
    per_block = block_rows * LANE
    padded = -(-n // per_block) * per_block
    rows = padded // LANE
    flat = jnp.pad(flat, (0, padded - n)).reshape(rows, LANE)

    grid = (rows // block_rows,)
    sin_q, cos_q = pl.pallas_call(
        functools.partial(_kernel, iterations=iterations),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(flat)
    return (
        sin_q.reshape(-1)[:n].reshape(shape),
        cos_q.reshape(-1)[:n].reshape(shape),
    )
