"""Pallas TPU kernel: universal CORDIC (Walther) on int32 blocks.

Generalizes ``kernels/cordic/cordic.py`` from circular rotation to the
full mode table — circular vectoring (atan2), hyperbolic vectoring
(sqrt, log), hyperbolic rotation (exp), and the composed tanh/sigmoid
paths (hyperbolic rotation + linear-vectoring division).  Each grid
step loads a (rows, 128) block of Q16.16 operands into VMEM and runs
the fully-unrolled shift-add iteration on the VPU; the atan/atanh
tables are baked in as immediates, exactly like the sincos kernel.

The op bodies are the *same functions* as ``repro.core.cordic`` — the
kernel adds only blocking/padding — so the NumPy-int64 oracles in
``ref.py`` pin down one bit-exact contract for both layers.  All ops
are total on the padding value 0 (atan2(0,0)=0, sqrt(0)=0, exp(0)=1,
log(0)=Q16.16 min, tanh(0)=0), so the tail padding is safe.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core.cordic import (
    HYPER_STAGES,
    atan2_q16_body,
    div_q16_body,
    exp_q16_body,
    log_q16_body,
    sigmoid_q16_body,
    sqrt_q16_body,
    tanh_q16_body,
)
from repro.compat import CompilerParams, default_interpret
from repro.kernels.cordic.cordic import DEFAULT_BLOCK_ROWS, LANE

__all__ = ["UNARY_OPS", "universal_kernel_call", "atan2_kernel_call", "div_kernel_call"]

#: op name -> elementwise Q16.16 body (shared with repro.core.cordic)
UNARY_OPS = {
    "sqrt": sqrt_q16_body,
    "exp": exp_q16_body,
    "log": log_q16_body,
    "tanh": tanh_q16_body,
    "sigmoid": sigmoid_q16_body,
}


def _unary_kernel(in_ref, out_ref, *, op: str, stages: int):
    out_ref[...] = UNARY_OPS[op](in_ref[...], stages)


def _atan2_kernel(y_ref, x_ref, out_ref, *, iterations: int, frac_bits: int):
    out_ref[...] = atan2_q16_body(y_ref[...], x_ref[...], iterations, frac_bits)


def _div_kernel(num_ref, den_ref, out_ref, *, iterations: int):
    out_ref[...] = div_q16_body(num_ref[...], den_ref[...], iterations)


def _blocked_call(kernel, inputs, *, block_rows: int, interpret: Optional[bool]):
    """Flatten int32 operands to (rows, 128) blocks, pad the tail with
    zeros, run the 1-output kernel over a parallel grid, restore shape."""
    if interpret is None:
        interpret = default_interpret()
    shape = inputs[0].shape
    flats = [jnp.ravel(jnp.asarray(v, jnp.int32)) for v in inputs]
    n = flats[0].shape[0]
    per_block = block_rows * LANE
    padded = -(-n // per_block) * per_block
    rows = padded // LANE
    flats = [jnp.pad(f, (0, padded - n)).reshape(rows, LANE) for f in flats]

    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(flats),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*flats)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(
    jax.jit, static_argnames=("op", "stages", "block_rows", "interpret")
)
def universal_kernel_call(
    w_q,
    *,
    op: str,
    stages: int = HYPER_STAGES,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
):
    """Apply a unary universal-CORDIC op (sqrt/exp/log/tanh/sigmoid) to
    a Q16.16 int32 array of any shape."""
    if op not in UNARY_OPS:
        raise ValueError(f"unknown universal op {op!r}; have {sorted(UNARY_OPS)}")
    kernel = functools.partial(_unary_kernel, op=op, stages=stages)
    return _blocked_call(kernel, [w_q], block_rows=block_rows, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("iterations", "frac_bits", "block_rows", "interpret")
)
def atan2_kernel_call(
    y_q,
    x_q,
    *,
    iterations: int = 16,
    frac_bits: int = 16,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
):
    """atan2(y, x) on Q(m.n) int32 arrays of any (matching) shape.
    ``frac_bits`` selects the output angle format (24 = the Q8.24
    ladder rung; operands are scale-invariant)."""
    kernel = functools.partial(_atan2_kernel, iterations=iterations, frac_bits=frac_bits)
    return _blocked_call(kernel, [y_q, x_q], block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("iterations", "block_rows", "interpret"))
def div_kernel_call(
    num_q,
    den_q,
    *,
    iterations: int = 17,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
):
    """Full-range linear-vectoring division num/den on Q16.16 int32
    arrays (div(0, 0) = 0, so the zero tail padding is safe)."""
    kernel = functools.partial(_div_kernel, iterations=iterations)
    return _blocked_call(kernel, [num_q, den_q], block_rows=block_rows, interpret=interpret)
