"""Public ops for the CORDIC kernels: float boundaries + RoPE tables +
the universal (Walther-mode) transcendental family."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cordic import HYPER_STAGES, exact_rope_phase_q16
from repro.core.qformat import Q16_16, from_fixed, to_fixed
from repro.kernels.cordic.cordic import cordic_kernel_call
from repro.kernels.cordic.universal import (
    atan2_kernel_call,
    div_kernel_call,
    universal_kernel_call,
)

__all__ = ["sincos", "rope_tables", "atan2", "div", "unary_op"]


@functools.partial(jax.jit, static_argnames=("iterations", "interpret"))
def sincos(theta, iterations: int = 16, interpret: Optional[bool] = None):
    """float angles -> (sin, cos) float32 through the Pallas kernel."""
    theta_q = to_fixed(theta, Q16_16)
    sin_q, cos_q = cordic_kernel_call(theta_q, iterations=iterations, interpret=interpret)
    return from_fixed(sin_q, Q16_16), from_fixed(cos_q, Q16_16)


@functools.partial(jax.jit, static_argnames=("iterations", "interpret", "dtype"))
def rope_tables(
    positions, f_hi, f_lo, iterations: int = 16, interpret: Optional[bool] = None, dtype=jnp.float32
):
    """Exact-phase RoPE sin/cos tables: Q0.64 phase (core.cordic) ->
    Pallas CORDIC -> (S, head_dim//2) tables in ``dtype``."""
    theta_q = exact_rope_phase_q16(positions[..., None], f_hi[None, :], f_lo[None, :])
    sin_q, cos_q = cordic_kernel_call(theta_q, iterations=iterations, interpret=interpret)
    return (
        from_fixed(sin_q, Q16_16, dtype=dtype),
        from_fixed(cos_q, Q16_16, dtype=dtype),
    )


@functools.partial(jax.jit, static_argnames=("iterations", "interpret"))
def atan2(y, x, iterations: int = 16, interpret: Optional[bool] = None):
    """float (y, x) -> atan2 float32 through the universal Pallas kernel."""
    out_q = atan2_kernel_call(
        to_fixed(y, Q16_16), to_fixed(x, Q16_16),
        iterations=iterations, interpret=interpret,
    )
    return from_fixed(out_q, Q16_16)


@functools.partial(jax.jit, static_argnames=("iterations", "interpret"))
def div(num, den, iterations: int = 17, interpret: Optional[bool] = None):
    """float (num, den) -> num/den float32 through the linear-vectoring
    Pallas kernel (ROADMAP ``div_q16`` public op)."""
    out_q = div_kernel_call(
        to_fixed(num, Q16_16), to_fixed(den, Q16_16),
        iterations=iterations, interpret=interpret,
    )
    return from_fixed(out_q, Q16_16)


@functools.partial(jax.jit, static_argnames=("op", "stages", "interpret"))
def unary_op(w, op: str, stages: int = HYPER_STAGES, interpret: Optional[bool] = None):
    """float -> float universal unary op (sqrt/exp/log/tanh/sigmoid)."""
    out_q = universal_kernel_call(
        to_fixed(w, Q16_16), op=op, stages=stages, interpret=interpret
    )
    return from_fixed(out_q, Q16_16)
