"""Public ops for the CORDIC kernel: float boundaries + RoPE tables."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cordic import exact_rope_phase_q16
from repro.core.qformat import Q16_16, from_fixed, to_fixed
from repro.kernels.cordic.cordic import cordic_kernel_call

__all__ = ["sincos", "rope_tables"]


@functools.partial(jax.jit, static_argnames=("iterations", "interpret"))
def sincos(theta, iterations: int = 16, interpret: bool = True):
    """float angles -> (sin, cos) float32 through the Pallas kernel."""
    theta_q = to_fixed(theta, Q16_16)
    sin_q, cos_q = cordic_kernel_call(theta_q, iterations=iterations, interpret=interpret)
    return from_fixed(sin_q, Q16_16), from_fixed(cos_q, Q16_16)


@functools.partial(jax.jit, static_argnames=("iterations", "interpret", "dtype"))
def rope_tables(
    positions, f_hi, f_lo, iterations: int = 16, interpret: bool = True, dtype=jnp.float32
):
    """Exact-phase RoPE sin/cos tables: Q0.64 phase (core.cordic) ->
    Pallas CORDIC -> (S, head_dim//2) tables in ``dtype``."""
    theta_q = exact_rope_phase_q16(positions[..., None], f_hi[None, :], f_lo[None, :])
    sin_q, cos_q = cordic_kernel_call(theta_q, iterations=iterations, interpret=interpret)
    return (
        from_fixed(sin_q, Q16_16, dtype=dtype),
        from_fixed(cos_q, Q16_16, dtype=dtype),
    )
