"""Public ops for the quantized matmul kernel.

``qmatmul``      — float in / float out W8A8 matmul through the Pallas
                   kernel (quantize -> int8 MXU -> deferred rescale).
``qmatmul_q16``  — Q16.16-raw output variant (the paper's native type).
``qmatmul_int16``— W8A16: activations as hi/lo int8 limbs (two kernel
                   passes + shift-combine), the paper's §8.1 "paired
                   registers" answer to the missing wide multiplier.
``qdot_ste``     — differentiable wrapper (straight-through estimator)
                   used by the FAST training path: quantized forward,
                   float backward.

``interpret=None`` auto-detects via ``repro.compat.default_interpret``:
compiled Mosaic kernels on TPU, interpreter everywhere else.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import dequantize_pow2, quantize_pow2
from repro.kernels.qmatmul.qmatmul import qmatmul_kernel_call

__all__ = ["qmatmul", "qmatmul_q16", "qmatmul_int16", "qdot_ste"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul(a, b, interpret: Optional[bool] = None):
    """float (M,K) x (K,N) -> float32 (M,N) via the W8A8 fast path."""
    aq = quantize_pow2(a, bits=8, axis=None)
    bq = quantize_pow2(b, bits=8, axis=1)  # per-output-channel
    return qmatmul_kernel_call(
        aq.q, bq.q, aq.exp, bq.exp.reshape(-1), epilogue="float", interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul_q16(a, b, interpret: Optional[bool] = None):
    """float x float -> raw Q16.16 int32 output (paper-native type)."""
    aq = quantize_pow2(a, bits=8, axis=None)
    bq = quantize_pow2(b, bits=8, axis=1)
    return qmatmul_kernel_call(
        aq.q, bq.q, aq.exp, bq.exp.reshape(-1), epilogue="q16", interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul_int16(a, b, interpret: Optional[bool] = None):
    """W8A16: 16-bit activations split into int8 limbs (paper §8.1).

    a is quantized to int16 with a per-tensor pow2 scale, then split:
        a16 = a_hi * 2**8 + a_lo,  a_hi = asr(a16, 8) in [-128, 127],
        a_lo = a16 & 0xFF in [0, 255].
    The unsigned low limb is made MXU-friendly (int8) by the standard
    zero-point trick: a_lo - 128, corrected with a column-sum term.
    Two kernel passes accumulate exactly; ONE deferred rescale total.
    """
    aq = quantize_pow2(a, bits=16, axis=None)
    bq = quantize_pow2(b, bits=8, axis=1)
    a16 = aq.q.astype(jnp.int32)
    a_hi = (a16 >> 8).astype(jnp.int8)
    a_lo_u = (a16 & 0xFF).astype(jnp.int32)
    a_lo = (a_lo_u - 128).astype(jnp.int8)

    zero_e = jnp.zeros((), jnp.int32)
    eb = bq.exp.reshape(-1)
    hi = qmatmul_kernel_call(a_hi, bq.q, zero_e, eb * 0, epilogue="int32", interpret=interpret)
    lo = qmatmul_kernel_call(a_lo, bq.q, zero_e, eb * 0, epilogue="int32", interpret=interpret)
    # zero-point correction: sum_k 128 * b[k, n]
    col = 128 * jnp.sum(bq.q.astype(jnp.int32), axis=0)  # (N,)
    acc = (hi << 8) + lo + col[None, :]
    scale = jnp.exp2((aq.exp + bq.exp.reshape(1, -1)).astype(jnp.float32))
    return acc.astype(jnp.float32) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def qdot_ste(a, b, interpret: Optional[bool] = None):
    """Quantized forward / float backward (straight-through estimator)."""
    return qmatmul(a, b, interpret=interpret)


def _qdot_fwd(a, b, interpret):
    return qmatmul(a, b, interpret=interpret), (a, b)


def _qdot_bwd(interpret, res, g):
    a, b = res
    return (
        jnp.matmul(g, b.T.astype(g.dtype)),
        jnp.matmul(a.T.astype(g.dtype), g),
    )


qdot_ste.defvjp(_qdot_fwd, _qdot_bwd)
