"""Pure NumPy-int64 oracle for the qmatmul Pallas kernel.

Defines the *contract* the kernel must match bit-exactly on the integer
paths (and exactly-up-to-f32 on the float epilogue): exact int32-safe
accumulation of int8 products, ONE deferred power-of-two correction per
output element (paper Eq. 18)."""

from __future__ import annotations

import numpy as np


def qmatmul_ref(a_q, b_q, ea, eb, epilogue: str = "float"):
    """a_q (M,K) int8, b_q (K,N) int8, ea scalar int, eb (N,) int."""
    a = np.asarray(a_q, np.int64)
    b = np.asarray(b_q, np.int64)
    acc = a @ b  # exact in int64 (products <= 2**14, K <= 2**17)
    assert np.all(np.abs(acc) < 2**31), "accumulation must fit int32"
    e = int(ea) + np.asarray(eb, np.int64)[None, :]
    if epilogue == "int32":
        return acc.astype(np.int32)
    if epilogue == "float":
        return (acc.astype(np.float64) * np.exp2(e.astype(np.float64))).astype(np.float32)
    if epilogue == "q16":
        s = e + 16
        out = np.where(
            s >= 0,
            acc << np.maximum(s, 0),
            (acc + (1 << np.maximum(-s - 1, 0)) * (s < 0)) >> np.maximum(-s, 0),
        )
        return out.astype(np.int32)
    raise ValueError(epilogue)


def quantize_pow2_ref(x, bits: int = 8, axis=None):
    """NumPy mirror of core.quantization.quantize_pow2."""
    x = np.asarray(x, np.float32)
    if axis is None:
        amax = np.max(np.abs(x))
        e = int(np.ceil(np.log2(max(amax, 1e-30)))) - (bits - 1) if amax > 0 else 0
        e_arr = np.int32(e)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = np.max(np.abs(x), axis=red, keepdims=True)
        e_arr = np.where(
            amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-30))).astype(np.int32) - (bits - 1), 0
        )
    qmax = 2 ** (bits - 1) - 1
    q = np.clip(np.round(x * np.exp2(-e_arr.astype(np.float64))), -qmax - 1, qmax)
    return q.astype({8: np.int8, 16: np.int16}[bits]), e_arr
