"""Pallas TPU kernel: tiled Q-format int8 matmul with deferred rescale.

This is the paper's C3 (cache-aware tiled matmul with deferred-shift
accumulation, Listing 3) re-derived for the TPU memory hierarchy:

* The paper sizes its tile from the ESP32 SRAM bank (``4 b**2 < 8 KB``
  => b = 32).  Here the BlockSpec tile is sized from the VMEM budget
  (``(bm*bk + bk*bn + 2*bm*bn) bytes`` within a few MiB, double
  buffered by the Pallas pipeline) and aligned to the MXU lane width
  (128).  Loop tiling IS BlockSpec — the index maps below are the
  paper's I/J/K block loops.
* The paper accumulates a K-tile in ``int64_t`` and shifts once.  The
  MXU accumulates int8xint8 products *natively and exactly* in int32
  (safe for K <= 2**17), and the single deferred correction is applied
  in the epilogue at the last K step: ONE rounding event per output
  element (paper Eq. 18), versus one per multiply in a
  quantize-per-product scheme.
* Q formats are per-channel powers of two (core/quantization.py), so
  the correction is a shift (q16 epilogue) or an exact exp2 scale
  (float epilogue) — never a true division.

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost ("arbitrary" semantics,
revisiting the same output/accumulator block); A/B blocks stream
through VMEM; the int32 accumulator lives in a VMEM scratch that
persists across the K steps of one (i, j) tile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import CompilerParams, default_interpret

__all__ = ["qmatmul_kernel_call", "DEFAULT_BM", "DEFAULT_BN", "DEFAULT_BK"]

# Derived from a ~2.5 MiB single-buffer working set (x2 for pipeline
# double-buffering stays well under VMEM), 128-aligned:
#   bm*bk + bk*bn (int8) + bm*bn (int32 acc + int32/f32 out)
DEFAULT_BM = 512
DEFAULT_BN = 512
DEFAULT_BK = 512


def _kernel(a_ref, b_ref, ea_ref, eb_ref, out_ref, acc_ref, *, nk: int, epilogue: str):
    """One (i, j, k) grid step.

    a_ref:  (bm, bk) int8      A tile
    b_ref:  (bk, bn) int8      B tile
    ea_ref: (1, 1)   int32     activation exponent (per-tensor)
    eb_ref: (1, bn)  int32     weight exponents (per-channel)
    out_ref:(bm, bn) int32/f32 output tile
    acc_ref:(bm, bn) int32     VMEM scratch accumulator
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: int8 x int8 -> exact int32 accumulation (the paper's widened
    # accumulator, natively).
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        e = ea_ref[0, 0] + eb_ref[0, :]  # (bn,) combined exponent
        if epilogue == "float":
            # exact power-of-two scale: one multiply, no rounding
            out_ref[...] = acc.astype(jnp.float32) * jnp.exp2(e.astype(jnp.float32))[None, :]
        elif epilogue == "q16":
            # deferred shift to Q16.16: raw = acc * 2**(e + 16)
            s = e + 16
            # s >= 0: left shift (exact); s < 0: round-half-up right shift
            sr = jnp.maximum(-s, 0)
            sl = jnp.maximum(s, 0)
            half = jnp.where(sr > 0, jnp.int32(1) << jnp.maximum(sr - 1, 0), 0)
            shifted = (acc + half[None, :]) >> sr[None, :]
            out_ref[...] = jnp.where(
                (s >= 0)[None, :], acc << sl[None, :], shifted
            ).astype(jnp.int32)
        else:  # 'int32' — raw accumulator (caller rescales)
            out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "epilogue", "interpret"),
)
def qmatmul_kernel_call(
    a_q,
    b_q,
    ea,
    eb,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    epilogue: str = "float",
    interpret: Optional[bool] = None,
):
    """Invoke the Pallas kernel on padded int8 operands.

    a_q: (M, K) int8;  b_q: (K, N) int8
    ea:  () or (1,1) int32 per-tensor activation exponent
    eb:  (N,) int32 per-channel weight exponents
    Returns (M, N) float32 (epilogue='float') or int32 Q16.16
    (epilogue='q16') or raw int32 (epilogue='int32').
    ``interpret=None`` auto-detects (compiled on TPU, interpreter off-TPU).
    """
    if interpret is None:
        interpret = default_interpret()
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2, (a_q.shape, b_q.shape)
    bm_, bn_, bk_ = min(bm, _rup(M, 8)), min(bn, _rup(N, 128)), min(bk, _rup(K, 128))

    Mp, Np, Kp = _rup(M, bm_), _rup(N, bn_), _rup(K, bk_)
    a_p = jnp.pad(a_q, ((0, Mp - M), (0, Kp - K)))
    b_p = jnp.pad(b_q, ((0, Kp - K), (0, Np - N)))
    eb_p = jnp.pad(jnp.asarray(eb, jnp.int32).reshape(1, N), ((0, 0), (0, Np - N)))
    ea_ = jnp.asarray(ea, jnp.int32).reshape(1, 1)

    nk = Kp // bk_
    out_dtype = jnp.float32 if epilogue == "float" else jnp.int32

    grid = (Mp // bm_, Np // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, epilogue=epilogue),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p, ea_, eb_p)
    return out[:M, :N]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
