"""NumPy oracle for the flash-attention kernel: plain materialized
softmax attention in float64 with identical masking semantics."""

from __future__ import annotations

import numpy as np


def attention_ref(q, k, v, *, scale, causal=True, window=None):
    """q (BH,S,D), k (BH,Skv,D), v (BH,Skv,Dv) -> (BH,S,Dv) float64."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    BH, S, D = q.shape
    Skv = k.shape[1]
    s = np.einsum("bsd,btd->bst", q, k) * scale
    q_pos = np.arange(S)[:, None]
    k_pos = np.arange(Skv)[None, :]
    valid = np.ones((S, Skv), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = np.where(valid[None], s, -np.inf)
    m = s.max(-1, keepdims=True)
    p = np.exp(s - m)
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bst,btd->bsd", p, v)
