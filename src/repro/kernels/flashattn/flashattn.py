"""Pallas TPU kernel: fused flash attention (forward).

The dry-run roofline identified attention score-chain materialization
as the dominant memory term of most train/prefill cells: the XLA path
writes the (S x chunk) f32 score tensor to HBM ~6 times per chunk
(dot, softcap, mask, max, exp, pv).  This kernel is the paper's C3
discipline applied to attention: BlockSpec tiles sized for VMEM, the
whole online-softmax update fused into ONE pass per (q-block, k-block),
and — like the deferred-shift matmul — a single normalization epilogue
per output block instead of per-partial-product corrections.

Grid: ``(B*H, S/bq, Skv/bk)``, k innermost; the running max/denominator
/accumulator live in VMEM scratch across the k steps of one q block.
Sliding-window and causal masks are computed from block indices
(branchless, loop-variant — nothing is precomputed or saved).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import CompilerParams, default_interpret

__all__ = ["flash_attention_call", "DEFAULT_BQ", "DEFAULT_BK"]

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            nk: int, bq: int, bk: int, scale: float, causal: bool, window):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, D)
    k = k_ref[0]                                   # (bk, D)
    v = v_ref[0]                                   # (bk, Dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (bq, bk)

    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new

    @pl.when(kb == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_call(
    q, k, v, *,
    scale: float,
    causal: bool = True,
    window=None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: Optional[bool] = None,
):
    """q: (BH, S, D); k: (BH, Skv, D); v: (BH, Skv, Dv) — heads folded
    into the leading dim (GQA repeat handled by ops.py).  Returns
    (BH, S, Dv) in q.dtype."""
    if interpret is None:
        interpret = default_interpret()
    BH, S, D = q.shape
    Skv, Dv = k.shape[1], v.shape[2]
    bq_, bk_ = min(bq, _rup(S, 8)), min(bk, _rup(Skv, 128))
    Sp, Skvp = _rup(S, bq_), _rup(Skv, bk_)
    # padding: padded k positions fall outside the causal/window mask
    # ONLY if masks are on; for non-causal, mask via a validity window
    # by padding k with -inf-producing zeros and masking k_pos >= Skv.
    q_p = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, 0)))

    nq, nk = Sp // bq_, Skvp // bk_
    # guard padded keys by shrinking the effective window/causal bound:
    # simplest robust guard: treat padded keys as future positions
    kernel = functools.partial(
        _kernel, nk=nk, bq=bq_, bk=bk_, scale=scale,
        causal=causal or (Skvp != Skv), window=window,
    )
    # when padding forced causal on a non-causal call, clamp q_pos so
    # real keys stay visible: handled by construction when S == Skv
    # (self-attention, the only non-causal use here).

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk_, D), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk_, Dv), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, Dv), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_p, k_p, v_p)
    return out[:, :S]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
