"""Public op: GQA-aware fused flash attention through the Pallas kernel."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flashattn.flashattn import flash_attention_call

__all__ = ["flash_attention"]


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None, interpret: Optional[bool] = None):
    """q: (B, S, H, D); k/v: (B, Skv, KV, D/Dv) -> (B, S, H, Dv).

    GQA: kv heads are repeated to H before folding (B, H) into the
    kernel's grid dimension.  Scale = D^-1/2, the models' convention.
    """
    B, S, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, Dv)

    out = flash_attention_call(
        qf, kf, vf, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        interpret=interpret,
    )
    return out.reshape(B, H, S, Dv).transpose(0, 2, 1, 3)
