"""Deterministic synthetic token pipeline.

Production shape: per-host sharded, deterministic in ``(step, host)``
so a restarted or replaced worker regenerates exactly the batches it
would have seen — the data-side half of fault tolerance (the
checkpoint provides the model-side half).

The generator is a counter-mode PRNG (threefry via jax.random on host
numpy here): batch i is a pure function of (seed, step), never of
pipeline state, so there is nothing to checkpoint and no drift after
elastic re-sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "host_shard"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 42                 # the paper's LCG seed
    num_hosts: int = 1
    host_id: int = 0
    # synthetic structure: repeated motifs make the LM loss actually
    # decrease, so examples/train_tiny_lm.py shows real learning curves
    motif_len: int = 16
    num_motifs: int = 64


def host_shard(cfg: DataConfig) -> slice:
    assert cfg.global_batch % cfg.num_hosts == 0, (cfg.global_batch, cfg.num_hosts)
    per = cfg.global_batch // cfg.num_hosts
    return slice(cfg.host_id * per, (cfg.host_id + 1) * per)


class SyntheticLM:
    """Batches of next-token-predictable synthetic text."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        self._motifs = base.integers(
            0, cfg.vocab, size=(cfg.num_motifs, cfg.motif_len), dtype=np.int32
        )

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step: the (host-local) batch for that step."""
        cfg = self.cfg
        sl = host_shard(cfg)
        rng = np.random.default_rng((cfg.seed, step))
        n_rows = cfg.global_batch
        reps = -(-(cfg.seq_len + 1) // cfg.motif_len)
        idx = rng.integers(0, cfg.num_motifs, size=(n_rows, reps))
        stream = self._motifs[idx].reshape(n_rows, -1)[:, : cfg.seq_len + 1]
        # sprinkle noise so the task is not trivially memorizable
        noise_mask = rng.random((n_rows, cfg.seq_len + 1)) < 0.02
        noise = rng.integers(0, cfg.vocab, size=(n_rows, cfg.seq_len + 1), dtype=np.int32)
        stream = np.where(noise_mask, noise, stream).astype(np.int32)
        local = stream[sl]
        return {
            "tokens": local[:, :-1],
            "labels": local[:, 1:],
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
