"""Q-format fixed-point arithmetic core (paper §3.1, §5.1; Listing 1).

Implements the paper's Q16.16 core — and the general Q(m.n) family —
on top of JAX int32/uint32 primitives.

TPU-native adaptation
---------------------
The paper's reference implementation relies on a 64-bit intermediate
product (``int64_t`` on the Xtensa LX6).  Neither the TPU vector unit
nor default (x64-disabled) JAX has a native 64-bit integer path, so the
widened product is computed with **paired 32-bit limbs** — exactly the
alternative the paper itself proposes in §8.1 ("paired int32 registers")
and the multi-limb scheme of §8.5.  All limb arithmetic below is
wrap-defined uint32/int32; the `ref`-side oracles (NumPy int64) verify
bit-exactness in tests.

Error properties (paper Eq. 6): with round-to-nearest the multiply
error is ``|eps| <= 2**-(n+1)`` (2**-17 for Q16.16); with the plain
floor shift of Listing 1 it is ``< 2**-n``.  Both modes are provided;
``rounding=True`` is the default and matches the paper's *stated* bound.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QFormat",
    "Q16_16",
    "Q8_24",
    "Q1_15",
    "Q8_8",
    "Q0_7",
    "Q2_6",
    "to_fixed",
    "from_fixed",
    "q_add",
    "q_sub",
    "q_add_sat",
    "q_sub_sat",
    "q_mul",
    "q_mul_sat",
    "q_neg",
    "widening_mul_i32",
    "shift_right_64",
    "add_64",
]

# NB: a NumPy scalar, deliberately NOT jnp: this module is imported
# lazily from inside traced functions (layers.py fast paths), and a
# module-level jnp constant created during a trace leaks that trace's
# tracer into every later jit (UnexpectedTracerError).
_U16_MASK = np.uint32(0xFFFF)


@dataclasses.dataclass(frozen=True)
class QFormat:
    """A signed Q(m.n) fixed-point format (paper §3.1, Eq. 1–2).

    ``int_bits`` includes the sign bit, matching the paper's convention
    (Q16.16 = 16 integer bits incl. sign + 16 fractional bits = 32-bit
    word).
    """

    int_bits: int
    frac_bits: int
    name: str = ""

    def __post_init__(self):
        total = self.int_bits + self.frac_bits
        if total not in (8, 16, 32):
            raise ValueError(f"Q{self.int_bits}.{self.frac_bits}: word width {total} unsupported")

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def dtype(self):
        return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[self.total_bits]

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def resolution(self) -> float:
        """Paper: 2**-n (1.526e-5 for Q16.16)."""
        return 2.0 ** (-self.frac_bits)

    @property
    def raw_min(self) -> int:
        return -(2 ** (self.total_bits - 1))

    @property
    def raw_max(self) -> int:
        return 2 ** (self.total_bits - 1) - 1

    @property
    def min_value(self) -> float:
        """Paper Eq. 2 lower bound: -2**(m-1)."""
        return self.raw_min / self.scale

    @property
    def max_value(self) -> float:
        """Paper Eq. 2 upper bound: 2**(m-1) - 2**-n."""
        return self.raw_max / self.scale

    def __repr__(self):  # pragma: no cover - cosmetic
        tag = f" ({self.name})" if self.name else ""
        return f"Q{self.int_bits}.{self.frac_bits}{tag}"


# The paper's format plus the narrower formats used by the TPU fast path.
Q16_16 = QFormat(16, 16, "paper Q16.16")
Q8_24 = QFormat(8, 24, "high-precision angle")
Q8_8 = QFormat(8, 8, "int16 activations")
Q1_15 = QFormat(1, 15, "int16 normalized")
Q0_7 = QFormat(1, 7, "int8 normalized")  # sign + 7 frac
Q2_6 = QFormat(2, 6, "int8 dynamic")


# ---------------------------------------------------------------------------
# Conversion (paper Listing 1: floatToQ / qToFloat)
# ---------------------------------------------------------------------------


def to_fixed(x, fmt: QFormat = Q16_16, *, saturate: bool = True):
    """Round-to-nearest float -> Q(m.n) raw integer (paper Eq. 1).

    Saturation is applied *after* the cast via masks: ``2**31 - 1`` is
    not exactly representable in float32, so a clip-then-cast would
    overflow at the positive boundary.
    """
    x = jnp.asarray(x)
    scaled = jnp.round(x.astype(jnp.float32) * fmt.scale)
    raw = scaled.astype(jnp.int32).astype(fmt.dtype)
    if saturate:
        # float bounds: 2.0**(total_bits-1) is exact in f32
        hi_f = jnp.float32(2.0 ** (fmt.total_bits - 1))
        over = scaled >= hi_f
        under = scaled < -hi_f
        raw = jnp.where(over, jnp.asarray(fmt.raw_max, fmt.dtype), raw)
        raw = jnp.where(under, jnp.asarray(fmt.raw_min, fmt.dtype), raw)
    return raw


def from_fixed(v, fmt: QFormat = Q16_16, dtype=jnp.float32):
    """Q(m.n) raw integer -> float (paper Listing 1 qToFloat)."""
    return jnp.asarray(v).astype(dtype) / jnp.asarray(fmt.scale, dtype)


# ---------------------------------------------------------------------------
# Exact add / sub (paper Eq. 3) + saturating variants (paper §3.1.2)
# ---------------------------------------------------------------------------


def q_add(a, b):
    """Exact Q addition — scaling factor preserved (paper Eq. 3).

    Wraps on overflow, matching the C ``addQ``.
    """
    return jnp.asarray(a) + jnp.asarray(b)


def q_sub(a, b):
    return jnp.asarray(a) - jnp.asarray(b)


def q_neg(a):
    return -jnp.asarray(a)


def _sat_bounds(dtype):
    info = jnp.iinfo(dtype)
    return info.min, info.max


def q_add_sat(a, b):
    """Saturating add: clamps instead of wrapping (paper §3.1.2)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    c = a + b  # wraps
    lo, hi = _sat_bounds(a.dtype)
    # overflow iff operands share a sign and result sign differs
    pos_over = (a > 0) & (b > 0) & (c < 0)
    neg_over = (a < 0) & (b < 0) & (c >= 0)
    c = jnp.where(pos_over, jnp.asarray(hi, a.dtype), c)
    c = jnp.where(neg_over, jnp.asarray(lo, a.dtype), c)
    return c


def q_sub_sat(a, b):
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    c = a - b
    lo, hi = _sat_bounds(a.dtype)
    pos_over = (a >= 0) & (b < 0) & (c < 0)
    neg_over = (a < 0) & (b > 0) & (c >= 0)
    c = jnp.where(pos_over, jnp.asarray(hi, a.dtype), c)
    c = jnp.where(neg_over, jnp.asarray(lo, a.dtype), c)
    return c


# ---------------------------------------------------------------------------
# Widening 32x32 -> 64 multiply via paired uint32 limbs (paper §8.1 / §8.5)
# ---------------------------------------------------------------------------


def widening_mul_i32(a, b) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact signed 32x32 -> 64-bit product as a (hi, lo) uint32 pair.

    Two's-complement: ``value = (hi << 32 | lo)`` interpreted as int64.
    Schoolbook on 16-bit half-limbs; the signed high word is recovered
    from the unsigned product with the standard correction
    ``hi_s = hi_u - (a<0 ? b : 0) - (b<0 ? a : 0)  (mod 2**32)``.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    au = a.astype(jnp.uint32)
    bu = b.astype(jnp.uint32)

    a_lo = au & _U16_MASK
    a_hi = au >> 16
    b_lo = bu & _U16_MASK
    b_hi = bu >> 16

    ll = a_lo * b_lo            # < 2**32, exact in uint32
    lh = a_lo * b_hi            # < 2**32
    hl = a_hi * b_lo            # < 2**32
    hh = a_hi * b_hi            # < 2**32

    # carry-aware combine: p = hh<<32 + (lh + hl)<<16 + ll
    mid = lh + (ll >> 16)       # no overflow: < 2**32 - 2**16 + 2**16
    mid_lo = mid & _U16_MASK
    mid2 = hl + mid_lo          # may carry into bit 32? max < 2**32 ✓ (both < 2**32-2**16 + 2**16)
    lo = (ll & _U16_MASK) | ((mid2 & _U16_MASK) << 16)
    hi_u = hh + (mid >> 16) + (mid2 >> 16)

    # signed correction for the high word
    corr = jnp.where(a < 0, bu, jnp.uint32(0)) + jnp.where(b < 0, au, jnp.uint32(0))
    hi = hi_u - corr
    return hi, lo


def add_64(hi, lo, addend_u32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(hi,lo) + small unsigned addend, with carry propagation."""
    lo2 = lo + addend_u32
    carry = (lo2 < lo).astype(jnp.uint32)
    return hi + carry, lo2


def add_64_pair(hi1, lo1, hi2, lo2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two's-complement 64-bit add of two (hi, lo) uint32 pairs."""
    lo = lo1 + lo2
    carry = (lo < lo1).astype(jnp.uint32)
    hi = hi1 + hi2 + carry
    return hi, lo


def shift_right_64(hi, lo, n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Arithmetic right shift of a two's-complement (hi, lo) pair by n<32."""
    if not 0 < n < 32:
        raise ValueError("shift must be in (0, 32)")
    lo2 = (lo >> n) | (hi << (32 - n))
    hi2 = (hi.astype(jnp.int32) >> n).astype(jnp.uint32)  # arithmetic
    return hi2, lo2


# ---------------------------------------------------------------------------
# Q multiplication (paper Eq. 4–6; Listing 1 mulQ / mulQ_sat)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("frac_bits", "rounding", "saturate"))
def q_mul(a, b, *, frac_bits: int = 16, rounding: bool = True, saturate: bool = False):
    """Q(m.n) multiply: 64-bit (paired-limb) intermediate, ONE shift.

    ``rounding=True``  -> round-to-nearest, |eps| <= 2**-(n+1) (paper Eq. 6)
    ``rounding=False`` -> floor shift exactly as Listing 1, |eps| < 2**-n
    ``saturate=True``  -> clamp to int32 range (Listing 1 mulQ_sat)
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    hi, lo = widening_mul_i32(a, b)
    if rounding:
        hi, lo = add_64(hi, lo, jnp.uint32(1 << (frac_bits - 1)))
    hi, lo = shift_right_64(hi, lo, frac_bits)
    result = lo.astype(jnp.int32)
    if saturate:
        # fits in int32 iff hi equals the sign extension of the low word
        sign_ext = (result >> 31).astype(jnp.uint32)
        fits = hi == sign_ext
        overflow_pos = hi.astype(jnp.int32) >= 0
        sat = jnp.where(overflow_pos, jnp.int32(0x7FFFFFFF), jnp.int32(-0x80000000))
        result = jnp.where(fits, result, sat)
    return result


def q_mul_sat(a, b, *, frac_bits: int = 16, rounding: bool = True):
    """Paper Listing 1 ``mulQ_sat``."""
    return q_mul(a, b, frac_bits=frac_bits, rounding=rounding, saturate=True)


# ---------------------------------------------------------------------------
# Static footprint accounting (paper §4.3.2: 88 bytes total)
# ---------------------------------------------------------------------------


def static_footprint_bytes(num_ops: int = 6, cordic_iters: int = 16) -> dict:
    """Reproduce the paper's static-memory decomposition.

    dispatch table: |F| x 4-byte pointers; CORDIC atan table:
    iters x 4 bytes of rodata.  (88 = 24 + 64 for the paper's numbers.)
    """
    dispatch = num_ops * 4
    atan_table = cordic_iters * 4
    return {
        "dispatch_table_bytes": dispatch,
        "cordic_table_bytes": atan_table,
        "total_bytes": dispatch + atan_table,
    }
