"""CORDIC math module (paper §3.2, §5.2; Listing 2) — universal edition.

Rotation-mode CORDIC computes ``sin``/``cos`` with adds and arithmetic
shifts only — no multipliers (Volder 1959; Walther 1971).  The paper
runs 16 iterations in Q16.16, giving an angular error bound of
``|eps_theta| <= 2**-16 rad ~= 1.526e-5`` (Eq. 14) from a 64-byte
arctangent table.

Universal CORDIC (beyond the paper's Listing 2)
-----------------------------------------------
The paper exercises only circular *rotation* mode, but Walther's
unified formulation — the very iteration the paper cites — covers three
coordinate systems x two directions on the same shift-add datapath:

====== ============ ======================= ==============================
 m      mode         rotation (drive z->0)   vectoring (drive y->0)
====== ============ ======================= ==============================
 +1     circular     sin, cos                atan2(y,x), K*sqrt(x^2+y^2)
 -1     hyperbolic   sinh, cosh -> exp,tanh  atanh(y/x) -> log; sqrt
  0     linear       multiply                divide
====== ============ ======================= ==============================

Gain constants: circular K = prod sqrt(1+2^-2i) -> 1.64676 (paper
Eq. 13); hyperbolic K_h = prod sqrt(1-2^-2i) over the iteration
schedule ~= 0.82816 (1/K_h ~= 1.20750).  Hyperbolic convergence
requires repeating iterations i = 4, 13, 40, ... (r_{j+1} = 3 r_j + 1);
with the repeats the convergence domain is |z| <= ~1.1182.

Derived Q16.16 operations and their range reductions:

* ``atan2_q16``   — circular vectoring in the right half-plane (x<0 is
  folded by point reflection, +/-pi restored from the sign of y);
  operands are pre-normalized so max(|x|,|y|) sits at bit 28, keeping
  the K-amplified magnitude inside int32.
* ``sqrt_q16``    — hyperbolic vectoring of (w+1/4, w-1/4): sqrt(w) =
  K_h^-1 * sqrt((w+1/4)^2 - (w-1/4)^2).  w is normalized to
  u in [0.5, 2) by an even power-of-two shift; the half-shift is
  reapplied to the result.  Internal datapath is Q3.29.
* ``exp_q16``     — hyperbolic rotation: e^r = cosh r + sinh r for
  r = t - k*ln2, |r| <= ln2/2; the 2^k is a final shift.  Saturates to
  Q16.16 max above ln(32768) and flushes to 0 below ln(2^-17).
* ``log_q16``     — hyperbolic vectoring: ln u = 2*atanh((u-1)/(u+1))
  for u in [1, 2) from an MSB normalization; ln w = ln u + k*ln2.
* ``tanh_q16``    — |t| <= 1: sinh/cosh from one hyperbolic rotation,
  divided in linear-vectoring mode; |t| > 1: (1 - e^-2|t|)/(1 + e^-2|t|)
  via ``exp_q16``, so the far tail needs no hyperbolic range extension.
* ``sigmoid_q16`` — (1 + tanh(t/2)) / 2.

Error bounds (Eq. 14 analogues; asserted in tests/test_universal_cordic.py,
measured against float64 oracles over each op's full input range):

* atan2:   |eps| <= 1e-4 rad
* sqrt:    |eps| <= 2^-16 + 3e-5 * sqrt(w)
* exp:     |eps| <= 2^-16 + 6e-5 * e^t   (below saturation)
* log:     |eps| <= 8e-5
* tanh:    |eps| <= 6e-5
* sigmoid: |eps| <= 5e-5

All six are dispatchable through ``MathEngine`` (FAST = these kernels,
PRECISE = the IEEE-754 jnp path); the Pallas TPU kernels in
``kernels/cordic/universal.py`` run the same bodies blockwise.

Differences from the paper's Listing 2 (documented in DESIGN.md):

* The listing's comment "sin is always in y; no negation needed" is
  wrong: after the fold ``theta -> theta -+ pi`` both ``cos`` *and*
  ``sin`` change sign (``sin(t - pi) = -sin t``).  We implement the
  corrected fold.
* The quadrant normalization here is **branchless** (`jnp.where`),
  which is the paper's own §8.2 future-work item — on a vector unit it
  is the natural formulation, eliminating the sin-jitter asymmetry the
  paper measured (coefficient 2.449).
* A full ``mod 2*pi`` range reduction precedes the fold, so any int32
  Q16.16 angle is accepted (the paper's listing assumes
  ``theta in [-pi, pi]``).

Beyond the paper: **exact fixed-point RoPE phase accumulation**.
``pos * inv_freq mod 2*pi`` is computed in Q0.64 *turns* with paired
uint32 limbs, so the phase error at position 524 288 is ~1e-9 rad
before CORDIC — versus ~3e-2 rad for the float32 product used by
typical RoPE implementations.  This is what makes the Q path *more*
accurate than fp32 for long-context rotary embeddings, not just
faster.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qformat import Q8_24, Q16_16, from_fixed, to_fixed

__all__ = [
    "ATAN_TABLE_Q16",
    "CORDIC_K_INV_Q16",
    "PI_Q16",
    "HALF_PI_Q16",
    "TWO_PI_Q16",
    "LN2_Q16",
    "INV_LN2_Q16",
    "EXP_SAT_HI_Q16",
    "EXP_FLUSH_LO_Q16",
    "HYPER_STAGES",
    "ITER_Q24",
    "angle_consts",
    "atan_table",
    "gain_inverse",
    "hyperbolic_schedule",
    "atanh_table",
    "hyper_gain_inverse",
    "cordic_sincos_q16",
    "cordic_sincos",
    "cordic_sincos24",
    "cordic_rotate_q16",
    "atan2_q16",
    "atan2_q24",
    "div_q16",
    "sqrt_q16",
    "exp_q16",
    "log_q16",
    "tanh_q16",
    "sigmoid_q16",
    "cordic_atan2",
    "cordic_atan2_24",
    "cordic_div",
    "cordic_sqrt",
    "cordic_exp",
    "cordic_log",
    "cordic_tanh",
    "cordic_sigmoid",
    "rope_inv_freq_q64",
    "exact_rope_phase_q16",
    "rope_tables_cordic",
]

_U16 = 1 << 16


def atan_table(iterations: int, frac_bits: int = 16) -> np.ndarray:
    """``round(atan(2**-i) * 2**frac_bits)`` for i in [0, iterations)."""
    scale = float(1 << frac_bits)
    return np.array(
        [int(round(math.atan(2.0 ** -i) * scale)) for i in range(iterations)],
        dtype=np.int32,
    )


def gain_inverse(iterations: int, frac_bits: int = 16) -> int:
    """``round(K_n**-1 * 2**frac_bits)`` (paper Eq. 13: K_inf = 1.64676...)."""
    k = 1.0
    for i in range(iterations):
        k *= math.sqrt(1.0 + 2.0 ** (-2 * i))
    return int(round((1.0 / k) * (1 << frac_bits)))


def angle_consts(frac_bits: int = 16) -> Tuple[int, int, int]:
    """(pi, pi/2, 2*pi) as raw Q(m.n) integers for any fraction width.

    2*pi in Q8.24 is ~1.05e8 — every format up to Q4.28 holds a full
    turn in int32, which is what bounds the ladder's angle formats.
    """
    scale = 1 << frac_bits
    return (
        int(round(math.pi * scale)),
        int(round(math.pi / 2 * scale)),
        int(round(2 * math.pi * scale)),
    )


# Paper's constants (verified identical to our generators):
ATAN_TABLE_Q16 = atan_table(16)                 # [51472, 30386, 16055, 8150, ...]
CORDIC_K_INV_Q16 = gain_inverse(16)             # 39797
PI_Q16, HALF_PI_Q16, TWO_PI_Q16 = angle_consts(16)   # 205887, 102944, 411775

#: default iteration count for the Q8.24 high-precision datapath: the
#: residual rotation atan(2**-23) ~= 1.2e-7 rad sits at one Q8.24 ulp.
ITER_Q24 = 24

assert CORDIC_K_INV_Q16 == 39797, "paper §5.2 constant mismatch"
assert PI_Q16 == 205887 and HALF_PI_Q16 == 102944, "paper §5.2 constants"
assert int(ATAN_TABLE_Q16[0]) == 51472, "paper Listing 2 atan(1) entry"


def _range_reduce_q(theta_q, frac_bits: int = 16):
    """Branchless reduction of any int32 Q(m.n) angle to [-pi/2, pi/2].

    Returns (reduced_angle, negate_flag).  negate applies to BOTH sin
    and cos (paper Listing 2's sin comment is incorrect — see module
    docstring).
    """
    pi_q, half_pi_q, two_pi_q = angle_consts(frac_bits)
    theta_q = jnp.asarray(theta_q, jnp.int32)
    two_pi = jnp.int32(two_pi_q)
    pi = jnp.int32(pi_q)
    half_pi = jnp.int32(half_pi_q)
    # floor-mod brings theta into [-pi, pi)
    r = jnp.remainder(theta_q + pi, two_pi) - pi
    hi = r > half_pi
    lo = r < -half_pi
    r = jnp.where(hi, r - pi, r)
    r = jnp.where(lo, r + pi, r)
    return r, hi | lo


def _range_reduce_q16(theta_q):
    return _range_reduce_q(theta_q, 16)


@partial(jax.jit, static_argnames=("iterations", "frac_bits"))
def cordic_sincos_q16(theta_q, iterations: int = 16, frac_bits: int = 16):
    """16-iteration rotation-mode CORDIC (paper Listing 2, corrected).

    Input/output are raw Q16.16 int32.  Vectorized over any shape; the
    iteration count is static so the loop fully unrolls (the paper
    relies on ``-O2`` unrolling; XLA does the same here).
    """
    table = atan_table(iterations, frac_bits)
    k_inv = gain_inverse(iterations, frac_bits)

    z, negate = _range_reduce_q(theta_q, frac_bits)
    x = jnp.full_like(z, k_inv)
    y = jnp.zeros_like(z)

    for i in range(iterations):
        d_pos = z >= 0
        x_shift = x >> i  # arithmetic shift: int32 >> is sign-preserving
        y_shift = y >> i
        x_new = jnp.where(d_pos, x - y_shift, x + y_shift)
        y_new = jnp.where(d_pos, y + x_shift, y - x_shift)
        z = jnp.where(d_pos, z - table[i], z + table[i])
        x, y = x_new, y_new

    cos_q = jnp.where(negate, -x, x)
    sin_q = jnp.where(negate, -y, y)
    return sin_q, cos_q


@partial(jax.jit, static_argnames=("iterations",))
def cordic_sincos(theta, iterations: int = 16):
    """Float in / float out convenience wrapper (pipeline boundary)."""
    theta_q = to_fixed(theta, Q16_16)
    sin_q, cos_q = cordic_sincos_q16(theta_q, iterations=iterations)
    return from_fixed(sin_q, Q16_16), from_fixed(cos_q, Q16_16)


@partial(jax.jit, static_argnames=("iterations",))
def cordic_sincos24(theta, iterations: int = ITER_Q24):
    """Q8.24 high-precision sincos (pipeline boundary).

    24 iterations on the Q8.24 datapath: angular error ~2e-6 rad
    (measured; asserted in tests/test_precision_ladder.py) vs the
    Q16.16 path's 8e-4-level output error — the angle-sensitive
    sensor-fusion rung of the ladder.  Input angles must satisfy
    |theta| < 128 - pi (the Q8.24 dynamic range); the sensor-fusion
    and RoPE callers reduce mod 2*pi upstream.
    """
    theta_q = to_fixed(theta, Q8_24)
    sin_q, cos_q = cordic_sincos_q16(theta_q, iterations=iterations, frac_bits=24)
    return from_fixed(sin_q, Q8_24), from_fixed(cos_q, Q8_24)


@partial(jax.jit, static_argnames=("iterations", "frac_bits"))
def cordic_rotate_q16(x_q, y_q, theta_q, iterations: int = 16, frac_bits: int = 16):
    """Rotate fixed-point vectors (x, y) by theta — multiplier-free.

    This is the CORDIC applied directly to data (e.g. RoPE pair
    rotation) rather than to the unit vector.  The K gain is folded in
    by pre-scaling with K^-1 via shift-add since K^-1 is a constant.
    """
    table = atan_table(iterations, frac_bits)
    k_inv = jnp.int32(gain_inverse(iterations, frac_bits))

    from repro.core.qformat import q_mul  # local import to avoid cycle at module load

    z, negate = _range_reduce_q16(theta_q)
    x = q_mul(jnp.asarray(x_q, jnp.int32), k_inv, frac_bits=frac_bits)
    y = q_mul(jnp.asarray(y_q, jnp.int32), k_inv, frac_bits=frac_bits)

    for i in range(iterations):
        d_pos = z >= 0
        x_shift = x >> i
        y_shift = y >> i
        x_new = jnp.where(d_pos, x - y_shift, x + y_shift)
        y_new = jnp.where(d_pos, y + x_shift, y - x_shift)
        z = jnp.where(d_pos, z - table[i], z + table[i])
        x, y = x_new, y_new

    x = jnp.where(negate, -x, x)
    y = jnp.where(negate, -y, y)
    return x, y


# ---------------------------------------------------------------------------
# Universal CORDIC (Walther): hyperbolic + linear modes, vectoring direction
# ---------------------------------------------------------------------------

#: Default hyperbolic stage count.  20 stages reach shift index 18
#: (with the 4/13 repeats), so the residual rotation angle is
#: atanh(2^-18) ~= 3.8e-6 — below one Q16.16 ulp.
HYPER_STAGES = 20

#: Internal fraction bits of the hyperbolic datapath (Q3.29): rotation
#: intermediates are bounded by cosh(1.55)/K_h < 3, so 3 integer bits
#: (incl. sign) suffice and 29 fraction bits keep the iteration noise
#: far below the Q16.16 output resolution.
_HFRAC = 29

LN2_Q16 = int(round(math.log(2.0) * _U16))          # 45426
INV_LN2_Q16 = int(round((1.0 / math.log(2.0)) * _U16))
EXP_SAT_HI_Q16 = int(round(math.log(32768.0) * _U16))   # exp saturates above
EXP_FLUSH_LO_Q16 = int(round(math.log(2.0 ** -17) * _U16))  # exp -> 0 below
_RAW_MAX = (1 << 31) - 1
_RAW_MIN = -(1 << 31)


def hyperbolic_schedule(stages: int) -> Tuple[int, ...]:
    """Shift indices 1, 2, 3, 4, 4, 5, ... with repeats at 4, 13, 40, ...

    The repeats (r_{j+1} = 3 r_j + 1) are required for hyperbolic
    convergence (Walther 1971); with them sum atanh(2^-i) ~= 1.1182.
    """
    idx, i, rep = [], 1, 4
    while len(idx) < stages:
        idx.append(i)
        if i == rep and len(idx) < stages:
            idx.append(i)
            rep = 3 * rep + 1
        i += 1
    return tuple(idx[:stages])


def atanh_table(schedule: Tuple[int, ...], frac_bits: int = _HFRAC) -> np.ndarray:
    """``round(atanh(2**-i) * 2**frac_bits)`` for each scheduled shift."""
    scale = float(1 << frac_bits)
    return np.array(
        [int(round(math.atanh(2.0 ** -i) * scale)) for i in schedule], dtype=np.int64
    )


def hyper_gain_inverse(schedule: Tuple[int, ...], frac_bits: int = _HFRAC) -> int:
    """``round(K_h**-1 * 2**frac_bits)``; K_h = prod sqrt(1-2^-2i) ~= 0.82816."""
    k = 1.0
    for i in schedule:
        k *= math.sqrt(1.0 - 2.0 ** (-2 * i))
    return int(round((1.0 / k) * (1 << frac_bits)))


def _i32(v: int):
    return jnp.int32(v)


def _clamp_raw(v):
    """Clamp INT32_MIN to INT32_MIN+1 so |v| and -v never wrap."""
    return jnp.maximum(jnp.asarray(v, jnp.int32), _i32(_RAW_MIN + 1))


def _ilog2(v):
    """Branchless floor(log2(v)) for v >= 1 (5-step binary cascade)."""
    v = jnp.asarray(v, jnp.int32)
    n = jnp.zeros_like(v)
    for s in (16, 8, 4, 2, 1):
        gt = v >= _i32(1 << s)
        n = n + jnp.where(gt, _i32(s), _i32(0))
        v = jnp.where(gt, v >> s, v)
    return n


def _shift_signed(v, s):
    """``v * 2**-s`` with a per-element signed shift count (s<0 => left)."""
    sr = jnp.maximum(s, 0)
    sl = jnp.maximum(-s, 0)
    return (v >> sr) << sl


def _round_shift_right(v, s):
    """Round-to-nearest arithmetic right shift by a per-element count >= 0."""
    half = jnp.where(s > 0, _i32(1) << jnp.maximum(s - 1, 0), _i32(0))
    return (v + half) >> s


def _hyper_vectoring(x, y, z, stages: int):
    """Drive y -> 0 (requires x > 0).  On exit x = K_h * sqrt(x0^2-y0^2)
    and z = z0 + atanh(y0/x0), both in the caller's fixed-point format
    (the atanh table is Q3.29 — callers keep z in Q3.29).

    x is non-increasing (each step subtracts |y|>>i), so the Q3.29
    intermediates never exceed their initial magnitude.
    """
    sched = hyperbolic_schedule(stages)
    table = atanh_table(sched, _HFRAC)
    for j, i in enumerate(sched):
        neg = y < 0
        xs = x >> i
        ys = y >> i
        t = _i32(int(table[j]))
        x, y, z = (
            jnp.where(neg, x + ys, x - ys),
            jnp.where(neg, y + xs, y - xs),
            jnp.where(neg, z - t, z + t),
        )
    return x, y, z


def _hyper_rotation(x, y, z, stages: int):
    """Drive z -> 0.  On exit (x, y) = K_h^-1-pre-scaled (cosh z0, sinh z0)
    when started from (K_h^-1, 0, z0); z is the Q3.29 residual angle."""
    sched = hyperbolic_schedule(stages)
    table = atanh_table(sched, _HFRAC)
    for j, i in enumerate(sched):
        pos = z >= 0
        xs = x >> i
        ys = y >> i
        t = _i32(int(table[j]))
        x, y, z = (
            jnp.where(pos, x + ys, x - ys),
            jnp.where(pos, y + xs, y - xs),
            jnp.where(pos, z - t, z + t),
        )
    return x, y, z


def _linear_div_q16(num, den, iterations: int = 17):
    """Linear-vectoring division: num/den in Q16.16, for den > 0 and
    |num| <= den (quotient in [-1, 1]).

    The denominator is normalized up to bit 29 first (the quotient is
    shift-invariant), so the y-update floor noise is ~2^-29 relative —
    the result is accurate to ~1 ulp.  Shift indices start at 0, giving
    a convergence range of sum 2^-i ~= 2.
    """
    num = jnp.asarray(num, jnp.int32)
    den = jnp.asarray(den, jnp.int32)
    b = _ilog2(jnp.maximum(den, 1))
    s = _i32(_HFRAC) - b  # normalize den into [2^29, 2^30)
    x = _shift_signed(den, -s)
    y = _shift_signed(num, -s)
    z = jnp.zeros_like(x)
    for i in range(iterations):
        pos = y >= 0
        xs = x >> i
        t = _i32(_U16 >> i)
        y = jnp.where(pos, y - xs, y + xs)
        z = jnp.where(pos, z + t, z - t)
    return z


def div_q16_body(num_q, den_q, iterations: int = 17):
    """Full-range linear-vectoring division num/den on Q16.16 (ROADMAP
    ``div_q16``).

    Normalization story: ``_linear_div_q16`` converges for quotients in
    (-2, 2) (shift schedule starting at 0, sum 2^-i = 2).  BOTH
    operands are pre-normalized to bit 29 — numerator left-shifts are
    exact, so no significand bits are ever discarded (a numerator
    right-shift would cost 2^-msb(den) relative error on small
    denominators) — and the quotient's net exponent
    ``e = msb(|num|) - msb(|den|)`` is applied to the result: rounded
    right-shift for e < 0, saturating left-shift for e > 0.  Error:
    |eps| <= 2**-15 * (1 + |num/den|) — one Q16.16 quantization step
    for sub-unit quotients, ~2**-15 relative above 1 (measured with 2x
    margin over the full operand range; asserted in
    tests/test_precision_ladder.py and gated in the benchmark smoke).

    Edge cases: den == 0 saturates to sign(num) * Q16.16 max (0/0 = 0);
    INT32_MIN operands are clamped one ulp up so |.| never wraps.
    """
    num = _clamp_raw(num_q)
    den = _clamp_raw(den_q)
    an = jnp.abs(num)
    ad = jnp.abs(den)
    bn = _ilog2(jnp.maximum(an, 1))
    bd = _ilog2(jnp.maximum(ad, 1))
    # normalize both significands to [2^29, 2^30): exact for the
    # numerator (left shift), <= 2^-28 relative for a denominator
    # above bit 29 (bd in {30}, right shift by <= 1)
    nn = _shift_signed(an, bn - _i32(_HFRAC))
    dd = _shift_signed(ad, bd - _i32(_HFRAC))
    z = _linear_div_q16(nn, jnp.maximum(dd, 1), iterations)  # in (0.5, 2) Q16.16
    e = bn - bd
    zr = _round_shift_right(z, jnp.maximum(-e, 0))
    sl = jnp.maximum(e, 0)
    fits = zr <= (_i32(_RAW_MAX) >> sl)
    mag = jnp.where(fits, zr << sl, _i32(_RAW_MAX))
    out = jnp.where((num < 0) != (den < 0), -mag, mag)
    sat = jnp.where(num > 0, _i32(_RAW_MAX), _i32(_RAW_MIN + 1))
    return jnp.where(
        jnp.asarray(den_q, jnp.int32) == 0,
        jnp.where(num == 0, _i32(0), sat),
        out,
    )


def atan2_q16_body(y_q, x_q, iterations: int = 16, frac_bits: int = 16):
    """Circular-vectoring atan2 on Q(m.n) operands; pure jnp, unjitted
    (shared with the Pallas kernel body).

    The operand normalization is scale-invariant, so ``frac_bits``
    only selects the *output* angle format (the atan accumulator
    table); ``frac_bits=24`` is the Q8.24 ladder rung.
    """
    y0 = _clamp_raw(y_q)
    x0 = _clamp_raw(x_q)
    table = atan_table(iterations, frac_bits)
    pi_q = angle_consts(frac_bits)[0]

    # fold x<0 to the right half-plane by point reflection; the +/-pi
    # restoration direction comes from the sign of the original y
    neg_x = x0 < 0
    x1 = jnp.where(neg_x, -x0, x0)
    y1 = jnp.where(neg_x, -y0, y0)

    # scale so max(|x|,|y|) lands in [2^28, 2^29): the circular gain
    # K ~= 1.647 then keeps the magnitude below 2^31 (atan2 is
    # scale-invariant, so both up- and down-shifts are free)
    m = jnp.maximum(jnp.abs(x1), jnp.abs(y1))
    s = _i32(28) - _ilog2(jnp.maximum(m, 1))
    x1 = _shift_signed(x1, -s)
    y1 = _shift_signed(y1, -s)

    z = jnp.zeros_like(x1)
    for i in range(iterations):
        neg = y1 < 0
        xs = x1 >> i
        ys = y1 >> i
        t = _i32(int(table[i]))
        x1, y1, z = (
            jnp.where(neg, x1 - ys, x1 + ys),
            jnp.where(neg, y1 + xs, y1 - xs),
            jnp.where(neg, z - t, z + t),
        )

    half_turn = jnp.where(y0 < 0, _i32(-pi_q), _i32(pi_q))
    out = jnp.where(neg_x, z + half_turn, z)
    return jnp.where((x0 == 0) & (y0 == 0), _i32(0), out)


def sqrt_q16_body(w_q, stages: int = HYPER_STAGES):
    """Hyperbolic-vectoring square root on Q16.16; w <= 0 returns 0."""
    w = _clamp_raw(w_q)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    # even-shift normalization: w = u * 2^s, s even, u in [0.5, 2)
    b = _ilog2(jnp.maximum(w, 1))
    s0 = b - _i32(16)
    s = jnp.where((s0 & 1) == 0, s0, s0 + 1)
    u = _shift_signed(w, s)                      # Q16.16 in [0.5, 2)
    u29 = u << (_HFRAC - 16)
    quarter = _i32(1 << (_HFRAC - 2))

    x, _, _ = _hyper_vectoring(u29 + quarter, u29 - quarter, jnp.zeros_like(u29), stages)
    from repro.core.qformat import q_mul

    r29 = q_mul(x, _i32(k_h_inv), frac_bits=_HFRAC)  # sqrt(u), Q3.29
    # back to Q16.16 with the half-shift folded in: s in [-16, 14] even,
    # so the net shift (29-16) - s/2 is always a right shift in [6, 21]
    out = _round_shift_right(r29, _i32(_HFRAC - 16) - (s >> 1))
    return jnp.where(w <= 0, _i32(0), out)


def exp_q16_body(t_q, stages: int = HYPER_STAGES):
    """Hyperbolic-rotation exponential on Q16.16 with ln2 argument
    reduction; saturates above ln(32768), flushes to 0 below ln(2^-17)."""
    from repro.core.qformat import q_mul

    t = jnp.asarray(t_q, jnp.int32)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    tc = jnp.clip(t, _i32(EXP_FLUSH_LO_Q16 - _U16), _i32(EXP_SAT_HI_Q16 + _U16))
    k = (q_mul(tc, _i32(INV_LN2_Q16)) + _i32(1 << 15)) >> 16  # round(t/ln2)
    r = tc - k * _i32(LN2_Q16)                                # |r| <= ~ln2/2

    x, y, _ = _hyper_rotation(
        jnp.full_like(t, k_h_inv), jnp.zeros_like(t), r << (_HFRAC - 16), stages
    )
    er = x + y                                  # e^r in Q3.29, in [0.70, 1.42]

    # e^t = e^r * 2^k: net right shift (29-16) - k, with saturation on
    # the left-shift (k > 13) side
    sh = _i32(_HFRAC - 16) - k
    rs = _round_shift_right(er, jnp.maximum(sh, 0))
    sl = jnp.maximum(-sh, 0)
    fits = rs <= (_i32(_RAW_MAX) >> sl)
    out = jnp.where(fits, rs << sl, _i32(_RAW_MAX))
    out = jnp.where(t >= _i32(EXP_SAT_HI_Q16), _i32(_RAW_MAX), out)
    return jnp.where(t <= _i32(EXP_FLUSH_LO_Q16), _i32(0), out)


def log_q16_body(w_q, stages: int = HYPER_STAGES):
    """Hyperbolic-vectoring natural log on Q16.16: ln w = 2*atanh((u-1)/(u+1))
    + k*ln2 for u = w*2^-k in [1, 2) ((u-1)/(u+1) in [0, 1/3), within
    the atanh convergence domain).  w <= 0 returns Q16.16 min."""
    w = _clamp_raw(w_q)
    b = _ilog2(jnp.maximum(w, 1))
    k = b - _i32(16)
    u = _shift_signed(w, k)                     # Q16.16 in [1, 2)
    u29 = u << (_HFRAC - 16)
    one29 = _i32(1 << _HFRAC)

    _, _, z = _hyper_vectoring(u29 + one29, u29 - one29, jnp.zeros_like(u29), stages)
    # ln u = 2*z: Q3.29 -> Q16.16 is >> (29-16-1) with rounding
    lnu = (z + _i32(1 << (_HFRAC - 18))) >> (_HFRAC - 17)
    return jnp.where(w <= 0, _i32(_RAW_MIN), lnu + k * _i32(LN2_Q16))


def tanh_q16_body(t_q, stages: int = HYPER_STAGES):
    """tanh on Q16.16: sinh/cosh + linear-vectoring divide for |t| <= 1,
    (1 - e^-2|t|)/(1 + e^-2|t|) via ``exp_q16_body`` for the tail."""
    t = _clamp_raw(t_q)
    at = jnp.abs(t)
    k_h_inv = hyper_gain_inverse(hyperbolic_schedule(stages), _HFRAC)

    # near path: one hyperbolic rotation at the clamped angle
    ts = jnp.minimum(at, _i32(_U16))
    x, y, _ = _hyper_rotation(
        jnp.full_like(t, k_h_inv), jnp.zeros_like(t), ts << (_HFRAC - 16), stages
    )
    near = _linear_div_q16(y >> (_HFRAC - 16), jnp.maximum(x >> (_HFRAC - 16), 1))

    # far path: e = e^-2|t| in (0, 0.135]; tanh = (1-e)/(1+e).  |t| is
    # clamped before the doubling shift so -2|t| cannot wrap int32.
    a2 = jnp.minimum(at, _i32(-EXP_FLUSH_LO_Q16))
    e = exp_q16_body(-(a2 << 1), stages)
    far = _linear_div_q16(_i32(_U16) - e, _i32(_U16) + e)

    # the q=1 division corner can overshoot by 1 ulp; |tanh| <= 1 exactly
    mag = jnp.minimum(jnp.where(at <= _i32(_U16), near, far), _i32(_U16))
    return jnp.where(t < 0, -mag, mag)


def sigmoid_q16_body(t_q, stages: int = HYPER_STAGES):
    """sigmoid(t) = (1 + tanh(t/2)) / 2 on Q16.16."""
    t = _clamp_raw(t_q)
    th = tanh_q16_body(t >> 1, stages)
    return (th + _i32(_U16 + 1)) >> 1


def _jit_q(body, static=("iterations",)):
    return partial(jax.jit, static_argnames=static)(body)


atan2_q16 = _jit_q(atan2_q16_body, static=("iterations", "frac_bits"))
div_q16 = _jit_q(div_q16_body)
sqrt_q16 = _jit_q(sqrt_q16_body, static=("stages",))
exp_q16 = _jit_q(exp_q16_body, static=("stages",))
log_q16 = _jit_q(log_q16_body, static=("stages",))
tanh_q16 = _jit_q(tanh_q16_body, static=("stages",))
sigmoid_q16 = _jit_q(sigmoid_q16_body, static=("stages",))


def atan2_q24(y_q, x_q, iterations: int = ITER_Q24):
    """Circular-vectoring atan2 with a Q8.24 output angle (ladder rung
    ``q8_24``); operands are Q8.24 raws (any common scale works —
    atan2 is scale-invariant)."""
    return atan2_q16(y_q, x_q, iterations=iterations, frac_bits=24)


# float-boundary convenience wrappers (pipeline boundary, like cordic_sincos)


@jax.jit
def cordic_atan2(y, x):
    return from_fixed(atan2_q16(to_fixed(y, Q16_16), to_fixed(x, Q16_16)), Q16_16)


@jax.jit
def cordic_atan2_24(y, x):
    """Q8.24 atan2 at the float boundary.  Operands are pre-normalized
    by max(|y|, |x|) so any float magnitude fits the Q8.24 word —
    atan2 is scale-invariant, so this costs accuracy nothing and keeps
    the high-precision rung total over the f32 range."""
    y = jnp.asarray(y, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    m = jnp.maximum(jnp.abs(y), jnp.abs(x))
    s = jnp.where(m > 0, m, jnp.float32(1.0))
    return from_fixed(atan2_q24(to_fixed(y / s, Q8_24), to_fixed(x / s, Q8_24)), Q8_24)


@jax.jit
def cordic_div(num, den):
    """Linear-vectoring division at the float boundary (engine op
    ``div``): saturates at the Q16.16 envelope like every FAST op."""
    return from_fixed(div_q16(to_fixed(num, Q16_16), to_fixed(den, Q16_16)), Q16_16)


@jax.jit
def cordic_sqrt(x):
    return from_fixed(sqrt_q16(to_fixed(x, Q16_16)), Q16_16)


@jax.jit
def cordic_exp(x):
    return from_fixed(exp_q16(to_fixed(x, Q16_16)), Q16_16)


@jax.jit
def cordic_log(x):
    return from_fixed(log_q16(to_fixed(x, Q16_16)), Q16_16)


@jax.jit
def cordic_tanh(x):
    return from_fixed(tanh_q16(to_fixed(x, Q16_16)), Q16_16)


@jax.jit
def cordic_sigmoid(x):
    return from_fixed(sigmoid_q16(to_fixed(x, Q16_16)), Q16_16)


# ---------------------------------------------------------------------------
# Exact long-context RoPE phase (beyond paper; uses paper §8.5 multi-limb)
# ---------------------------------------------------------------------------


def rope_inv_freq_q64(head_dim: int, base: float = 10000.0) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pair rotary frequency as an exact Q0.64 fraction of a *turn*.

    ``f_j = base**(-2j/d) / (2*pi)`` encoded as (hi, lo) uint32 limbs of
    ``round(f_j * 2**64)``.  Computed host-side with Python integers.
    """
    half = head_dim // 2
    hi = np.zeros((half,), np.uint32)
    lo = np.zeros((half,), np.uint32)
    for j in range(half):
        turns = (base ** (-2.0 * j / head_dim)) / (2.0 * math.pi)
        q = int(round(turns * float(1 << 64)))
        q = min(q, (1 << 64) - 1)
        hi[j] = (q >> 32) & 0xFFFFFFFF
        lo[j] = q & 0xFFFFFFFF
    return hi, lo


@jax.jit
def exact_rope_phase_q16(positions, f_hi, f_lo):
    """``(pos * f) mod 1`` turn, exactly, then scaled to Q16.16 radians.

    positions: integer array (any shape), values < 2**32.
    f_hi/f_lo: uint32 Q0.64 turn fractions, shape broadcastable against
    positions (typically positions[..., None] x f[None, :]).

    Exactness: ``pos * f mod 2**64`` keeps only the fractional turn —
    integer turns wrap away for free.  One widening u32 multiply plus a
    wrapping u32 multiply; the result is the top 32 fractional bits
    (Q0.32 turns), then one more widening multiply by 2*pi in Q16.16.
    Total phase error <= 2**-33 turns + Q16.16 quantization.
    """
    pos = jnp.asarray(positions).astype(jnp.uint32)
    f_hi = jnp.asarray(f_hi, jnp.uint32)
    f_lo = jnp.asarray(f_lo, jnp.uint32)

    # 64-bit fraction: frac = (pos * (f_hi*2^32 + f_lo)) mod 2^64
    #   hi word = (pos*f_hi mod 2^32) + carry_hi(pos*f_lo)
    lo_prod_hi, _lo_prod_lo = _widening_mul_u32(pos, f_lo)
    frac_hi = pos * f_hi + lo_prod_hi  # wrapping u32: mod 2^32 is what we want
    # theta = frac (Q0.32 turns) * 2*pi (Q16.16) -> Q16.48; round to Q16.16
    t_hi, t_lo = _widening_mul_u32(frac_hi, jnp.uint32(TWO_PI_Q16))
    round_bit = (t_lo >> 31) & jnp.uint32(1)
    theta = (t_hi + round_bit).astype(jnp.int32)  # in [0, 2*pi) Q16.16, fits easily
    return theta


def _widening_mul_u32(a, b) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unsigned 32x32 -> 64 product as (hi, lo) uint32 limbs."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & mask, a >> 16
    b_lo, b_hi = b & mask, b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid = lh + (ll >> 16)
    mid2 = hl + (mid & mask)
    lo = (ll & mask) | ((mid2 & mask) << 16)
    hi = hh + (mid >> 16) + (mid2 >> 16)
    return hi, lo


@partial(jax.jit, static_argnames=("iterations", "dtype"))
def rope_tables_cordic(positions, f_hi, f_lo, iterations: int = 16, dtype=jnp.float32):
    """sin/cos rotary tables via exact phase + CORDIC.

    positions: (S,) int array.  Returns (sin, cos) of shape
    (S, head_dim//2) in ``dtype``.
    """
    theta_q = exact_rope_phase_q16(positions[..., None], f_hi[None, :], f_lo[None, :])
    sin_q, cos_q = cordic_sincos_q16(theta_q, iterations=iterations)
    return (
        from_fixed(sin_q, Q16_16, dtype=dtype),
        from_fixed(cos_q, Q16_16, dtype=dtype),
    )
