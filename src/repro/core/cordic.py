"""CORDIC trigonometric module (paper §3.2, §5.2; Listing 2).

Rotation-mode CORDIC computes ``sin``/``cos`` with adds and arithmetic
shifts only — no multipliers (Volder 1959; Walther 1971).  The paper
runs 16 iterations in Q16.16, giving an angular error bound of
``|eps_theta| <= 2**-16 rad ~= 1.526e-5`` (Eq. 14) from a 64-byte
arctangent table.

Differences from the paper's Listing 2 (documented in DESIGN.md):

* The listing's comment "sin is always in y; no negation needed" is
  wrong: after the fold ``theta -> theta -+ pi`` both ``cos`` *and*
  ``sin`` change sign (``sin(t - pi) = -sin t``).  We implement the
  corrected fold.
* The quadrant normalization here is **branchless** (`jnp.where`),
  which is the paper's own §8.2 future-work item — on a vector unit it
  is the natural formulation, eliminating the sin-jitter asymmetry the
  paper measured (coefficient 2.449).
* A full ``mod 2*pi`` range reduction precedes the fold, so any int32
  Q16.16 angle is accepted (the paper's listing assumes
  ``theta in [-pi, pi]``).

Beyond the paper: **exact fixed-point RoPE phase accumulation**.
``pos * inv_freq mod 2*pi`` is computed in Q0.64 *turns* with paired
uint32 limbs, so the phase error at position 524 288 is ~1e-9 rad
before CORDIC — versus ~3e-2 rad for the float32 product used by
typical RoPE implementations.  This is what makes the Q path *more*
accurate than fp32 for long-context rotary embeddings, not just
faster.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qformat import Q16_16, from_fixed, to_fixed

__all__ = [
    "ATAN_TABLE_Q16",
    "CORDIC_K_INV_Q16",
    "PI_Q16",
    "HALF_PI_Q16",
    "TWO_PI_Q16",
    "atan_table",
    "gain_inverse",
    "cordic_sincos_q16",
    "cordic_sincos",
    "cordic_rotate_q16",
    "rope_inv_freq_q64",
    "exact_rope_phase_q16",
    "rope_tables_cordic",
]

_U16 = 1 << 16


def atan_table(iterations: int, frac_bits: int = 16) -> np.ndarray:
    """``round(atan(2**-i) * 2**frac_bits)`` for i in [0, iterations)."""
    scale = float(1 << frac_bits)
    return np.array(
        [int(round(math.atan(2.0 ** -i) * scale)) for i in range(iterations)],
        dtype=np.int32,
    )


def gain_inverse(iterations: int, frac_bits: int = 16) -> int:
    """``round(K_n**-1 * 2**frac_bits)`` (paper Eq. 13: K_inf = 1.64676...)."""
    k = 1.0
    for i in range(iterations):
        k *= math.sqrt(1.0 + 2.0 ** (-2 * i))
    return int(round((1.0 / k) * (1 << frac_bits)))


# Paper's constants (verified identical to our generators):
ATAN_TABLE_Q16 = atan_table(16)                 # [51472, 30386, 16055, 8150, ...]
CORDIC_K_INV_Q16 = gain_inverse(16)             # 39797
PI_Q16 = int(round(math.pi * _U16))             # 205887
HALF_PI_Q16 = int(round(math.pi / 2 * _U16))    # 102944
TWO_PI_Q16 = int(round(2 * math.pi * _U16))     # 411775

assert CORDIC_K_INV_Q16 == 39797, "paper §5.2 constant mismatch"
assert PI_Q16 == 205887 and HALF_PI_Q16 == 102944, "paper §5.2 constants"
assert int(ATAN_TABLE_Q16[0]) == 51472, "paper Listing 2 atan(1) entry"


def _range_reduce_q16(theta_q):
    """Branchless reduction of any int32 Q16.16 angle to [-pi/2, pi/2].

    Returns (reduced_angle, negate_flag).  negate applies to BOTH sin
    and cos (paper Listing 2's sin comment is incorrect — see module
    docstring).
    """
    theta_q = jnp.asarray(theta_q, jnp.int32)
    two_pi = jnp.int32(TWO_PI_Q16)
    pi = jnp.int32(PI_Q16)
    half_pi = jnp.int32(HALF_PI_Q16)
    # floor-mod brings theta into [-pi, pi)
    r = jnp.remainder(theta_q + pi, two_pi) - pi
    hi = r > half_pi
    lo = r < -half_pi
    r = jnp.where(hi, r - pi, r)
    r = jnp.where(lo, r + pi, r)
    return r, hi | lo


@partial(jax.jit, static_argnames=("iterations", "frac_bits"))
def cordic_sincos_q16(theta_q, iterations: int = 16, frac_bits: int = 16):
    """16-iteration rotation-mode CORDIC (paper Listing 2, corrected).

    Input/output are raw Q16.16 int32.  Vectorized over any shape; the
    iteration count is static so the loop fully unrolls (the paper
    relies on ``-O2`` unrolling; XLA does the same here).
    """
    table = atan_table(iterations, frac_bits)
    k_inv = gain_inverse(iterations, frac_bits)

    z, negate = _range_reduce_q16(theta_q)
    x = jnp.full_like(z, k_inv)
    y = jnp.zeros_like(z)

    for i in range(iterations):
        d_pos = z >= 0
        x_shift = x >> i  # arithmetic shift: int32 >> is sign-preserving
        y_shift = y >> i
        x_new = jnp.where(d_pos, x - y_shift, x + y_shift)
        y_new = jnp.where(d_pos, y + x_shift, y - x_shift)
        z = jnp.where(d_pos, z - table[i], z + table[i])
        x, y = x_new, y_new

    cos_q = jnp.where(negate, -x, x)
    sin_q = jnp.where(negate, -y, y)
    return sin_q, cos_q


@partial(jax.jit, static_argnames=("iterations",))
def cordic_sincos(theta, iterations: int = 16):
    """Float in / float out convenience wrapper (pipeline boundary)."""
    theta_q = to_fixed(theta, Q16_16)
    sin_q, cos_q = cordic_sincos_q16(theta_q, iterations=iterations)
    return from_fixed(sin_q, Q16_16), from_fixed(cos_q, Q16_16)


@partial(jax.jit, static_argnames=("iterations", "frac_bits"))
def cordic_rotate_q16(x_q, y_q, theta_q, iterations: int = 16, frac_bits: int = 16):
    """Rotate fixed-point vectors (x, y) by theta — multiplier-free.

    This is the CORDIC applied directly to data (e.g. RoPE pair
    rotation) rather than to the unit vector.  The K gain is folded in
    by pre-scaling with K^-1 via shift-add since K^-1 is a constant.
    """
    table = atan_table(iterations, frac_bits)
    k_inv = jnp.int32(gain_inverse(iterations, frac_bits))

    from repro.core.qformat import q_mul  # local import to avoid cycle at module load

    z, negate = _range_reduce_q16(theta_q)
    x = q_mul(jnp.asarray(x_q, jnp.int32), k_inv, frac_bits=frac_bits)
    y = q_mul(jnp.asarray(y_q, jnp.int32), k_inv, frac_bits=frac_bits)

    for i in range(iterations):
        d_pos = z >= 0
        x_shift = x >> i
        y_shift = y >> i
        x_new = jnp.where(d_pos, x - y_shift, x + y_shift)
        y_new = jnp.where(d_pos, y + x_shift, y - x_shift)
        z = jnp.where(d_pos, z - table[i], z + table[i])
        x, y = x_new, y_new

    x = jnp.where(negate, -x, x)
    y = jnp.where(negate, -y, y)
    return x, y


# ---------------------------------------------------------------------------
# Exact long-context RoPE phase (beyond paper; uses paper §8.5 multi-limb)
# ---------------------------------------------------------------------------


def rope_inv_freq_q64(head_dim: int, base: float = 10000.0) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pair rotary frequency as an exact Q0.64 fraction of a *turn*.

    ``f_j = base**(-2j/d) / (2*pi)`` encoded as (hi, lo) uint32 limbs of
    ``round(f_j * 2**64)``.  Computed host-side with Python integers.
    """
    half = head_dim // 2
    hi = np.zeros((half,), np.uint32)
    lo = np.zeros((half,), np.uint32)
    for j in range(half):
        turns = (base ** (-2.0 * j / head_dim)) / (2.0 * math.pi)
        q = int(round(turns * float(1 << 64)))
        q = min(q, (1 << 64) - 1)
        hi[j] = (q >> 32) & 0xFFFFFFFF
        lo[j] = q & 0xFFFFFFFF
    return hi, lo


@jax.jit
def exact_rope_phase_q16(positions, f_hi, f_lo):
    """``(pos * f) mod 1`` turn, exactly, then scaled to Q16.16 radians.

    positions: integer array (any shape), values < 2**32.
    f_hi/f_lo: uint32 Q0.64 turn fractions, shape broadcastable against
    positions (typically positions[..., None] x f[None, :]).

    Exactness: ``pos * f mod 2**64`` keeps only the fractional turn —
    integer turns wrap away for free.  One widening u32 multiply plus a
    wrapping u32 multiply; the result is the top 32 fractional bits
    (Q0.32 turns), then one more widening multiply by 2*pi in Q16.16.
    Total phase error <= 2**-33 turns + Q16.16 quantization.
    """
    pos = jnp.asarray(positions).astype(jnp.uint32)
    f_hi = jnp.asarray(f_hi, jnp.uint32)
    f_lo = jnp.asarray(f_lo, jnp.uint32)

    # 64-bit fraction: frac = (pos * (f_hi*2^32 + f_lo)) mod 2^64
    #   hi word = (pos*f_hi mod 2^32) + carry_hi(pos*f_lo)
    lo_prod_hi, _lo_prod_lo = _widening_mul_u32(pos, f_lo)
    frac_hi = pos * f_hi + lo_prod_hi  # wrapping u32: mod 2^32 is what we want
    # theta = frac (Q0.32 turns) * 2*pi (Q16.16) -> Q16.48; round to Q16.16
    t_hi, t_lo = _widening_mul_u32(frac_hi, jnp.uint32(TWO_PI_Q16))
    round_bit = (t_lo >> 31) & jnp.uint32(1)
    theta = (t_hi + round_bit).astype(jnp.int32)  # in [0, 2*pi) Q16.16, fits easily
    return theta


def _widening_mul_u32(a, b) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unsigned 32x32 -> 64 product as (hi, lo) uint32 limbs."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & mask, a >> 16
    b_lo, b_hi = b & mask, b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid = lh + (ll >> 16)
    mid2 = hl + (mid & mask)
    lo = (ll & mask) | ((mid2 & mask) << 16)
    hi = hh + (mid >> 16) + (mid2 >> 16)
    return hi, lo


@partial(jax.jit, static_argnames=("iterations", "dtype"))
def rope_tables_cordic(positions, f_hi, f_lo, iterations: int = 16, dtype=jnp.float32):
    """sin/cos rotary tables via exact phase + CORDIC.

    positions: (S,) int array.  Returns (sin, cos) of shape
    (S, head_dim//2) in ``dtype``.
    """
    theta_q = exact_rope_phase_q16(positions[..., None], f_hi[None, :], f_lo[None, :])
    sin_q, cos_q = cordic_sincos_q16(theta_q, iterations=iterations)
    return (
        from_fixed(sin_q, Q16_16, dtype=dtype),
        from_fixed(cos_q, Q16_16, dtype=dtype),
    )
