"""Dynamic precision arbiter — beyond-paper extension of C4, ladder
edition.

The paper leaves the FAST/PRECISE choice to "the application layer"
(§7.2: CORDIC for trig, FPU for small matrices).  At training scale the
application-layer signal is numerics health: quantized (FAST) steps are
cheaper but can destabilize optimization.  The arbiter watches loss and
gradient-norm telemetry and *recommends* transitions along a precision
ladder, which the engine executes through the two-phase barrier at step
boundaries — the paper's "explicit, safe, costless" choice made
adaptive.

The ladder generalizes the original binary state machine: entries are
ordered cheapest -> most precise (defaults to the compat pair
``(Mode.FAST, Mode.PRECISE)``; pass level names like
``("q8_8", "q16_16", "q8_24", "f32")`` for multi-tier stepping).

Policy (hysteresis stepping):
  step UP (more precise) one rung on  (a) grad-norm spike
                      > spike_factor x running median, or
                      (b) loss regression > regress_tol over the window.
  jump to the TOP rung on non-finite loss/grad — a hard safety signal
                      that bypasses the cooldown (a NaN loss means every
                      further step at this rung is wasted; flapping
                      protection must not delay the rescue).
  step DOWN one rung after `stable_steps` consecutive healthy steps,
                      with a cooldown to prevent flapping.

Spike/regression escalations and all demotions honor the cooldown, and
any unhealthy step resets the ``stable_steps`` demotion counter.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Optional, Tuple

import numpy as np

from repro.core.precision import Mode

__all__ = ["ArbiterConfig", "PrecisionArbiter", "SlotArbiterConfig", "SlotArbiter"]


@dataclass(frozen=True)
class ArbiterConfig:
    spike_factor: float = 8.0        # grad-norm spike threshold vs running median
    regress_tol: float = 0.25        # fractional loss regression that trips escalation
    window: int = 32                 # telemetry window
    stable_steps: int = 64           # healthy steps before stepping back down
    cooldown_steps: int = 16         # minimum steps between switches
    #: ordered cheapest -> most precise; entries are whatever the engine
    #: accepts (Mode compat aliases or ladder level names).
    ladder: Tuple[Any, ...] = (Mode.FAST, Mode.PRECISE)
    start_mode: Any = Mode.FAST      # must be a ladder entry


@dataclass
class PrecisionArbiter:
    config: ArbiterConfig = field(default_factory=ArbiterConfig)

    def __post_init__(self):
        cfg = self.config
        if not cfg.ladder:
            raise ValueError("arbiter ladder must have at least one entry")
        self._ladder = tuple(cfg.ladder)
        try:
            self._idx = self._ladder.index(cfg.start_mode)
        except ValueError:
            raise ValueError(
                f"start_mode {cfg.start_mode!r} is not in the ladder {self._ladder!r}"
            ) from None
        self._losses: Deque[float] = deque(maxlen=cfg.window)
        self._gnorms: Deque[float] = deque(maxlen=cfg.window)
        self._stable = 0
        self._last_switch_step = -(10**9)
        self.decisions: list = []

    # -- state --------------------------------------------------------------

    @property
    def mode(self) -> Any:
        """The current ladder entry (compat: a Mode for the default ladder)."""
        return self._ladder[self._idx]

    @property
    def ladder(self) -> Tuple[Any, ...]:
        return self._ladder

    @property
    def rung(self) -> int:
        """Index of the current entry (0 = cheapest)."""
        return self._idx

    # -- helpers -----------------------------------------------------------

    @staticmethod
    def _median(values) -> float:
        s = sorted(values)
        n = len(s)
        if n == 0:
            return 0.0
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def _unhealthy(self, loss: float, grad_norm: float) -> Optional[str]:
        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            return "non-finite"
        if len(self._gnorms) >= 8:
            med = self._median(self._gnorms)
            if med > 0 and grad_norm > self.config.spike_factor * med:
                return f"grad-spike {grad_norm:.3g} > {self.config.spike_factor}x med {med:.3g}"
        if len(self._losses) >= 8:
            recent = self._median(list(self._losses)[-4:])
            past = self._median(list(self._losses)[:4])
            if past > 0 and recent > past * (1.0 + self.config.regress_tol):
                return f"loss-regression {past:.4g} -> {recent:.4g}"
        return None

    def _switch(self, step: int, idx: int, reason: str):
        self._idx = idx
        self._last_switch_step = step
        self._stable = 0
        entry = self._ladder[idx]
        self.decisions.append((step, entry, reason))
        return entry

    # -- main entry ---------------------------------------------------------

    def observe(self, step: int, loss: float, grad_norm: float) -> Optional[Any]:
        """Feed one step's telemetry; returns a ladder entry if a switch
        is recommended, else None.  Non-finite steps are NOT added to the
        telemetry window (they would poison the medians)."""
        reason = self._unhealthy(loss, grad_norm)
        cooled = step - self._last_switch_step >= self.config.cooldown_steps
        # non-finite loss is a hard failure: never wait out the cooldown
        forced = reason == "non-finite"

        if reason is None:
            self._losses.append(loss)
            self._gnorms.append(grad_norm)
            self._stable += 1
        else:
            self._stable = 0

        top = len(self._ladder) - 1
        if reason is not None and self._idx < top and (cooled or forced):
            # non-finite jumps straight to the most precise rung; spikes
            # and regressions escalate one rung at a time
            return self._switch(step, top if forced else self._idx + 1, reason)

        if (
            self._idx > 0
            and reason is None
            and self._stable >= self.config.stable_steps
            and cooled
        ):
            return self._switch(step, self._idx - 1, "stable")

        return None


# ---------------------------------------------------------------------------
# per-slot (per-request) vectorized arbiter — the serving edition
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SlotArbiterConfig:
    """Hysteresis policy for PER-REQUEST precision in the continuous-
    batching server (one ladder position per device slot).

    The training arbiter watches loss/grad-norm; a serving request has
    neither, so the per-slot signals are the request's own numerics
    health, pulled with the same (B,)-sized host sync as the EOS check:

    * ``nonfinite`` — any non-finite logit this step (hard failure:
      jump the slot to the TOP rung, bypassing the cooldown);
    * ``amplitude`` — max |logit|; above ``amp_threshold`` the fixed-
      point headroom is at risk (Q16.16 saturates at 2^15), so the slot
      steps UP one rung.

    ``stable_steps`` consecutive healthy steps step a slot back DOWN
    one rung — but never below the slot's *floor* (the rung the request
    asked for at admission): escalations are recoverable, the client's
    requested precision is a contract.  ``cooldown_steps`` separates
    consecutive switches of the same slot (NaN rescue excepted) — the
    same flapping protection as the training arbiter, vectorized.
    """

    n_levels: int = 2
    start_idx: int = 0               # rung a fresh request starts at (0 = cheapest)
    amp_threshold: float = 1e4       # |logit| escalation threshold (Q16.16 headroom)
    stable_steps: int = 8            # healthy steps before stepping back down
    cooldown_steps: int = 4          # min steps between switches of one slot
    #: speculative-decoding acceptance signal: a slot whose measured
    #: draft acceptance rate stays below ``accept_threshold`` for
    #: ``accept_patience`` consecutive measurements escalates its DRAFT
    #: rung one step (cheap drafts that keep getting rejected cost more
    #: than they save — a pure throughput signal; the f32 verify pass
    #: keeps the output distribution fixed regardless).
    accept_threshold: float = 0.5
    accept_patience: int = 4


class SlotArbiter:
    """Vectorized hysteresis state over ``n_slots`` serving slots.

    All state is host-side numpy (the decisions gate which jitted
    level-passes run, so they are host control flow by construction).
    ``observe`` consumes one decode step's per-slot signals and returns
    the updated per-slot level indices.
    """

    def __init__(self, n_slots: int, config: SlotArbiterConfig = SlotArbiterConfig(),
                 on_switch=None):
        if not 0 <= config.start_idx < config.n_levels:
            raise ValueError(f"start_idx {config.start_idx} outside ladder of {config.n_levels}")
        self.config = config
        self.n_slots = n_slots
        #: optional observer ``(step, slot, old_idx, new_idx, reason) ->
        #: None`` called on every switch — the serving telemetry's
        #: escalation counter/trace hook (kept as a plain callback so
        #: core/ stays import-independent of the telemetry layer).
        self.on_switch = on_switch
        self.idx = np.full((n_slots,), config.start_idx, np.int32)
        self.floor = np.full((n_slots,), config.start_idx, np.int32)
        self._stable = np.zeros((n_slots,), np.int32)
        self._low_accept = np.zeros((n_slots,), np.int32)
        self._last_switch = np.full((n_slots,), -(10**9), np.int64)
        #: recent (step, slot, old_idx, new_idx, reason) — bounded: a
        #: long-lived server must not grow state with lifetime traffic
        self.switches: deque = deque(maxlen=256)

    def reset_slot(self, slot: int, start_idx: Optional[int] = None) -> None:
        """Admission: a new request takes over the slot with fresh
        hysteresis state (levels never leak across requests).  The
        request's starting rung becomes the slot's demotion floor."""
        idx = self.config.start_idx if start_idx is None else int(start_idx)
        if not 0 <= idx < self.config.n_levels:
            raise ValueError(f"start_idx {idx} outside ladder of {self.config.n_levels}")
        self.idx[slot] = idx
        self.floor[slot] = idx
        self._stable[slot] = 0
        self._low_accept[slot] = 0
        self._last_switch[slot] = -(10**9)

    def observe(self, step: int, nonfinite, amplitude, active=None,
                acceptance=None) -> np.ndarray:
        """Feed one step's (n_slots,) signals; returns the new per-slot
        level indices.  ``active`` masks out empty slots (their state is
        frozen until the next admission).

        ``acceptance`` (optional, (n_slots,) float): measured speculative
        draft-acceptance rate in [0, 1]; NaN (or a negative value) marks
        slots with no measurement this step — their low-acceptance
        counter is left untouched.  Sustained low acceptance escalates
        one rung; the NaN rescue always takes precedence (a non-finite
        logit means the CURRENT rung's numerics are broken, which is a
        correctness signal, not a throughput one)."""
        cfg = self.config
        nonfinite = np.asarray(nonfinite, bool)
        amplitude = np.asarray(amplitude, np.float32)
        active = np.ones((self.n_slots,), bool) if active is None else np.asarray(active, bool)
        top = cfg.n_levels - 1

        cooled = (step - self._last_switch) >= cfg.cooldown_steps
        unhealthy = nonfinite | (amplitude > cfg.amp_threshold)

        self._stable = np.where(active & ~unhealthy, self._stable + 1, self._stable)
        self._stable[active & unhealthy] = 0

        if acceptance is not None:
            acceptance = np.asarray(acceptance, np.float32)
            measured = active & np.isfinite(acceptance) & (acceptance >= 0.0)
            low = measured & (acceptance < cfg.accept_threshold)
            self._low_accept = np.where(low, self._low_accept + 1, self._low_accept)
            self._low_accept[measured & ~low] = 0

        new_idx = self.idx.copy()
        # NaN rescue: straight to the top rung, no cooldown wait
        rescue = active & nonfinite & (self.idx < top)
        new_idx[rescue] = top
        # amplitude escalation: one rung, cooldown honored
        esc = active & ~nonfinite & (amplitude > cfg.amp_threshold) & (self.idx < top) & cooled
        new_idx[esc] = self.idx[esc] + 1
        # acceptance escalation: sustained low draft acceptance, one
        # rung, cooldown honored; health signals take precedence
        esc_acc = (active & ~unhealthy & (self._low_accept >= cfg.accept_patience)
                   & (self.idx < top) & cooled)
        new_idx[esc_acc] = self.idx[esc_acc] + 1
        self._low_accept[esc_acc] = 0
        # demotion: stable long enough, cooldown honored, floor respected
        dem = (active & ~unhealthy & ~esc_acc & (self.idx > self.floor)
               & (self._stable >= cfg.stable_steps) & cooled)
        new_idx[dem] = self.idx[dem] - 1

        changed = new_idx != self.idx
        self._last_switch[changed] = step
        self._stable[changed] = 0
        for s in np.nonzero(changed)[0]:
            reason = ("non-finite" if rescue[s]
                      else "amplitude" if esc[s]
                      else "acceptance" if esc_acc[s]
                      else "stable")
            self.switches.append((step, int(s), int(self.idx[s]), int(new_idx[s]), reason))
            if self.on_switch is not None:
                self.on_switch(step, int(s), int(self.idx[s]), int(new_idx[s]), reason)
        self.idx = new_idx
        return self.idx
