"""Dynamic precision arbiter — beyond-paper extension of C4.

The paper leaves the FAST/PRECISE choice to "the application layer"
(§7.2: CORDIC for trig, FPU for small matrices).  At training scale the
application-layer signal is numerics health: quantized (FAST) steps are
cheaper but can destabilize optimization.  The arbiter watches loss and
gradient-norm telemetry and *recommends* mode transitions, which the
engine executes through the two-phase barrier at step boundaries — the
paper's "explicit, safe, costless" choice made adaptive.

Policy (hysteresis state machine):
  FAST -> PRECISE on  (a) non-finite loss, (b) grad-norm spike
                      > spike_factor x running median, or
                      (c) loss regression > regress_tol over the window.
  PRECISE -> FAST after `stable_steps` consecutive healthy steps,
                      with a cooldown to prevent flapping.

Non-finite telemetry is a hard safety signal: it forces the fallback
even inside the cooldown window (a NaN loss in FAST mode means every
further FAST step is wasted — flapping protection must not delay the
rescue).  Spike/regression fallbacks and all promotions still honor
the cooldown, and any unhealthy step resets the ``stable_steps``
promotion counter.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

from repro.core.precision import Mode

__all__ = ["ArbiterConfig", "PrecisionArbiter"]


@dataclass(frozen=True)
class ArbiterConfig:
    spike_factor: float = 8.0        # grad-norm spike threshold vs running median
    regress_tol: float = 0.25        # fractional loss regression that trips fallback
    window: int = 32                 # telemetry window
    stable_steps: int = 64           # healthy steps before promoting back to FAST
    cooldown_steps: int = 16         # minimum steps between switches
    start_mode: Mode = Mode.FAST


@dataclass
class PrecisionArbiter:
    config: ArbiterConfig = field(default_factory=ArbiterConfig)

    def __post_init__(self):
        self.mode: Mode = self.config.start_mode
        self._losses: Deque[float] = deque(maxlen=self.config.window)
        self._gnorms: Deque[float] = deque(maxlen=self.config.window)
        self._stable = 0
        self._last_switch_step = -(10**9)
        self.decisions: list = []

    # -- helpers -----------------------------------------------------------

    @staticmethod
    def _median(values) -> float:
        s = sorted(values)
        n = len(s)
        if n == 0:
            return 0.0
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def _unhealthy(self, loss: float, grad_norm: float) -> Optional[str]:
        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            return "non-finite"
        if len(self._gnorms) >= 8:
            med = self._median(self._gnorms)
            if med > 0 and grad_norm > self.config.spike_factor * med:
                return f"grad-spike {grad_norm:.3g} > {self.config.spike_factor}x med {med:.3g}"
        if len(self._losses) >= 8:
            recent = self._median(list(self._losses)[-4:])
            past = self._median(list(self._losses)[:4])
            if past > 0 and recent > past * (1.0 + self.config.regress_tol):
                return f"loss-regression {past:.4g} -> {recent:.4g}"
        return None

    # -- main entry ---------------------------------------------------------

    def observe(self, step: int, loss: float, grad_norm: float) -> Optional[Mode]:
        """Feed one step's telemetry; returns a Mode if a switch is
        recommended, else None.  Non-finite steps are NOT added to the
        telemetry window (they would poison the medians)."""
        reason = self._unhealthy(loss, grad_norm)
        cooled = step - self._last_switch_step >= self.config.cooldown_steps
        # non-finite loss is a hard failure: never wait out the cooldown
        forced = reason == "non-finite"

        if reason is None:
            self._losses.append(loss)
            self._gnorms.append(grad_norm)
            self._stable += 1
        else:
            self._stable = 0

        if self.mode is Mode.FAST and reason is not None and (cooled or forced):
            self.mode = Mode.PRECISE
            self._last_switch_step = step
            self._stable = 0
            self.decisions.append((step, Mode.PRECISE, reason))
            return Mode.PRECISE

        if (
            self.mode is Mode.PRECISE
            and reason is None
            and self._stable >= self.config.stable_steps
            and cooled
        ):
            self.mode = Mode.FAST
            self._last_switch_step = step
            self._stable = 0
            self.decisions.append((step, Mode.FAST, "stable"))
            return Mode.FAST

        return None
