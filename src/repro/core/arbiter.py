"""Dynamic precision arbiter — beyond-paper extension of C4, ladder
edition.

The paper leaves the FAST/PRECISE choice to "the application layer"
(§7.2: CORDIC for trig, FPU for small matrices).  At training scale the
application-layer signal is numerics health: quantized (FAST) steps are
cheaper but can destabilize optimization.  The arbiter watches loss and
gradient-norm telemetry and *recommends* transitions along a precision
ladder, which the engine executes through the two-phase barrier at step
boundaries — the paper's "explicit, safe, costless" choice made
adaptive.

The ladder generalizes the original binary state machine: entries are
ordered cheapest -> most precise (defaults to the compat pair
``(Mode.FAST, Mode.PRECISE)``; pass level names like
``("q8_8", "q16_16", "q8_24", "f32")`` for multi-tier stepping).

Policy (hysteresis stepping):
  step UP (more precise) one rung on  (a) grad-norm spike
                      > spike_factor x running median, or
                      (b) loss regression > regress_tol over the window.
  jump to the TOP rung on non-finite loss/grad — a hard safety signal
                      that bypasses the cooldown (a NaN loss means every
                      further step at this rung is wasted; flapping
                      protection must not delay the rescue).
  step DOWN one rung after `stable_steps` consecutive healthy steps,
                      with a cooldown to prevent flapping.

Spike/regression escalations and all demotions honor the cooldown, and
any unhealthy step resets the ``stable_steps`` demotion counter.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Optional, Tuple

from repro.core.precision import Mode

__all__ = ["ArbiterConfig", "PrecisionArbiter"]


@dataclass(frozen=True)
class ArbiterConfig:
    spike_factor: float = 8.0        # grad-norm spike threshold vs running median
    regress_tol: float = 0.25        # fractional loss regression that trips escalation
    window: int = 32                 # telemetry window
    stable_steps: int = 64           # healthy steps before stepping back down
    cooldown_steps: int = 16         # minimum steps between switches
    #: ordered cheapest -> most precise; entries are whatever the engine
    #: accepts (Mode compat aliases or ladder level names).
    ladder: Tuple[Any, ...] = (Mode.FAST, Mode.PRECISE)
    start_mode: Any = Mode.FAST      # must be a ladder entry


@dataclass
class PrecisionArbiter:
    config: ArbiterConfig = field(default_factory=ArbiterConfig)

    def __post_init__(self):
        cfg = self.config
        if not cfg.ladder:
            raise ValueError("arbiter ladder must have at least one entry")
        self._ladder = tuple(cfg.ladder)
        try:
            self._idx = self._ladder.index(cfg.start_mode)
        except ValueError:
            raise ValueError(
                f"start_mode {cfg.start_mode!r} is not in the ladder {self._ladder!r}"
            ) from None
        self._losses: Deque[float] = deque(maxlen=cfg.window)
        self._gnorms: Deque[float] = deque(maxlen=cfg.window)
        self._stable = 0
        self._last_switch_step = -(10**9)
        self.decisions: list = []

    # -- state --------------------------------------------------------------

    @property
    def mode(self) -> Any:
        """The current ladder entry (compat: a Mode for the default ladder)."""
        return self._ladder[self._idx]

    @property
    def ladder(self) -> Tuple[Any, ...]:
        return self._ladder

    @property
    def rung(self) -> int:
        """Index of the current entry (0 = cheapest)."""
        return self._idx

    # -- helpers -----------------------------------------------------------

    @staticmethod
    def _median(values) -> float:
        s = sorted(values)
        n = len(s)
        if n == 0:
            return 0.0
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def _unhealthy(self, loss: float, grad_norm: float) -> Optional[str]:
        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            return "non-finite"
        if len(self._gnorms) >= 8:
            med = self._median(self._gnorms)
            if med > 0 and grad_norm > self.config.spike_factor * med:
                return f"grad-spike {grad_norm:.3g} > {self.config.spike_factor}x med {med:.3g}"
        if len(self._losses) >= 8:
            recent = self._median(list(self._losses)[-4:])
            past = self._median(list(self._losses)[:4])
            if past > 0 and recent > past * (1.0 + self.config.regress_tol):
                return f"loss-regression {past:.4g} -> {recent:.4g}"
        return None

    def _switch(self, step: int, idx: int, reason: str):
        self._idx = idx
        self._last_switch_step = step
        self._stable = 0
        entry = self._ladder[idx]
        self.decisions.append((step, entry, reason))
        return entry

    # -- main entry ---------------------------------------------------------

    def observe(self, step: int, loss: float, grad_norm: float) -> Optional[Any]:
        """Feed one step's telemetry; returns a ladder entry if a switch
        is recommended, else None.  Non-finite steps are NOT added to the
        telemetry window (they would poison the medians)."""
        reason = self._unhealthy(loss, grad_norm)
        cooled = step - self._last_switch_step >= self.config.cooldown_steps
        # non-finite loss is a hard failure: never wait out the cooldown
        forced = reason == "non-finite"

        if reason is None:
            self._losses.append(loss)
            self._gnorms.append(grad_norm)
            self._stable += 1
        else:
            self._stable = 0

        top = len(self._ladder) - 1
        if reason is not None and self._idx < top and (cooled or forced):
            # non-finite jumps straight to the most precise rung; spikes
            # and regressions escalate one rung at a time
            return self._switch(step, top if forced else self._idx + 1, reason)

        if (
            self._idx > 0
            and reason is None
            and self._stable >= self.config.stable_steps
            and cooled
        ):
            return self._switch(step, self._idx - 1, "stable")

        return None
