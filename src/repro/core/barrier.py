"""Two-phase mode-transition barrier (paper §4.3.1), SPMD edition.

The paper's protocol on FreeRTOS:

1. *Suspension phase* — Core 0 notifies the worker; the worker finishes
   its in-flight operation and signals readiness via a semaphore.
2. *Transition phase* — Core 0 swaps the dispatch table and releases.

On a JAX SPMD deployment the analogous hazards are (a) asynchronous
dispatch — a step may still be executing on device when the host wants
to switch — and (b) multi-host divergence — hosts must switch at the
same step boundary or the executables' collectives deadlock.

Phase 1 therefore (a) blocks on the in-flight device values and (b)
reaches cross-host agreement; phase 2 performs the swap.  Agreement
uses ``multihost_sync`` — a tiny all-reduce across processes — which is
a no-op in single-process deployments (and in this CPU container).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

__all__ = ["TwoPhaseBarrier", "multihost_sync"]


def multihost_sync(tag: int = 0) -> None:
    """Cross-host agreement point.

    With >1 JAX processes, runs a 1-element psum across all devices so
    every host reaches this line before any host proceeds — the SPMD
    analogue of the paper's notify/semaphore pair.  Single-process:
    no-op (there is nobody to disagree with).
    """
    if jax.process_count() > 1:  # pragma: no cover - needs real multi-host
        import jax.numpy as jnp

        val = jnp.ones((jax.local_device_count(),), jnp.int32) * (tag + 1)
        out = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")(val)
        jax.block_until_ready(out)


@dataclass
class BarrierEvent:
    quiesce_s: float
    swap_s: float
    total_s: float


@dataclass
class TwoPhaseBarrier:
    """quiesce -> agree -> swap, with per-event timing."""

    sync_fn: Callable[[], None] = multihost_sync
    events: list = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def transition(self, *, inflight: Any, swap_fn: Callable[[], None]) -> BarrierEvent:
        with self._lock:
            t0 = time.perf_counter()
            # Phase 1a: the in-flight operation completes (paper: worker
            # drains its current job and blocks).
            if inflight is not None:
                try:
                    jax.block_until_ready(inflight)
                except Exception:
                    pass  # host-only values have nothing to block on
            # Phase 1b: cross-host agreement (paper: xTaskNotify + semaphore).
            self.sync_fn()
            t1 = time.perf_counter()
            # Phase 2: the swap itself — a reference assignment.
            swap_fn()
            t2 = time.perf_counter()
            ev = BarrierEvent(quiesce_s=t1 - t0, swap_s=t2 - t1, total_s=t2 - t0)
            self.events.append(ev)
            return ev
