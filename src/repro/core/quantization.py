"""Power-of-two Q-format tensor quantization (paper C1 scaled to tensors).

The paper fixes the binary point globally (Q16.16).  For tensor-level
workloads the engine generalizes this to *per-channel* Q formats: each
channel c is stored as ``q[c] * 2**exp[c]`` with an integer exponent —
i.e. a Q(m.n) format chosen per channel.  Because every scale is a
power of two, all rescaling remains *shift-only* (the paper's deferred
single-shift correction survives intact: an int32 MXU accumulator is
corrected by one shift/exponent-add per output element).

Also hosts the Q-format gradient compressor (paper §8.6's distributed
extension): int8 quantization with error feedback, used by
``optim/grad_compress.py`` to shrink the DP all-reduce 4x.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "QTensor",
    "quantize_pow2",
    "dequantize_pow2",
    "quantize_q16",
    "compress_with_feedback",
]


class QTensor(NamedTuple):
    """A quantized tensor: ``value ~= q * 2.0**exp`` (per-channel)."""

    q: jnp.ndarray          # int8 / int16 / int32 payload
    exp: jnp.ndarray        # int32 per-channel exponents (broadcastable)
    axis: Optional[int] = None  # channel axis the exponents follow

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def shape(self):
        return self.q.shape


def _storage_dtype(bits: int):
    return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[bits]


@partial(jax.jit, static_argnames=("bits", "axis"))
def quantize_pow2(x, bits: int = 8, axis: Optional[int] = None) -> QTensor:
    """Quantize to a power-of-two-scaled integer grid.

    exp is chosen per channel (or per tensor when axis is None) as the
    smallest e with ``amax / 2**e <= 2**(bits-1)``, so the payload fits
    the signed ``bits``-wide integer after round-to-nearest (the single
    rounding event — paper Eq. 6 applies per element).
    """
    x = jnp.asarray(x, jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    # e = ceil(log2(amax)) - (bits-1); amax==0 -> e=0
    safe = jnp.maximum(amax, jnp.float32(1e-30))
    e = jnp.ceil(jnp.log2(safe)).astype(jnp.int32) - (bits - 1)
    e = jnp.where(amax > 0, e, jnp.zeros_like(e, jnp.int32))
    scale = jnp.exp2(-e.astype(jnp.float32))  # 2**-e, exact for |e| < 127
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x * scale), -qmax - 1, qmax).astype(_storage_dtype(bits))
    return QTensor(q=q, exp=e, axis=axis)


def dequantize_pow2(qt: QTensor, dtype=jnp.float32):
    """Exact shift-only dequantization: ``q * 2.0**exp``."""
    return qt.q.astype(dtype) * jnp.exp2(qt.exp.astype(dtype))


def quantize_q16(x):
    """Fixed global Q16.16 (the paper's format) as a QTensor."""
    from repro.core.qformat import Q16_16, to_fixed

    q = to_fixed(x, Q16_16)
    return QTensor(q=q, exp=jnp.int32(-16), axis=None)


@partial(jax.jit, static_argnames=("bits",))
def compress_with_feedback(
    grad, residual, bits: int = 8
) -> Tuple[QTensor, jnp.ndarray]:
    """Error-feedback Q-format gradient compression.

    Quantizes ``grad + residual`` to ``bits`` with a per-tensor
    power-of-two scale and returns the new residual (the quantization
    error), so the compression error is *recirculated*, not lost —
    the standard EF-SGD trick, expressed in the paper's Q-format terms.
    """
    g = jnp.asarray(grad, jnp.float32) + residual
    qt = quantize_pow2(g, bits=bits, axis=None)
    new_residual = g - dequantize_pow2(qt)
    return qt, new_residual
