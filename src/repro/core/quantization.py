"""Power-of-two Q-format tensor quantization (paper C1 scaled to tensors).

The paper fixes the binary point globally (Q16.16).  For tensor-level
workloads the engine generalizes this to *per-channel* Q formats: each
channel c is stored as ``q[c] * 2**exp[c]`` with an integer exponent —
i.e. a Q(m.n) format chosen per channel.  Because every scale is a
power of two, all rescaling remains *shift-only* (the paper's deferred
single-shift correction survives intact: an int32 MXU accumulator is
corrected by one shift/exponent-add per output element).

Also hosts the Q-format gradient compressor (paper §8.6's distributed
extension): int8 quantization with error feedback, used by
``optim/grad_compress.py`` to shrink the DP all-reduce 4x.
"""

from __future__ import annotations

import threading
from functools import partial
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = [
    "QTensor",
    "quantize_pow2",
    "dequantize_pow2",
    "quantize_q16",
    "compress_with_feedback",
    "QuantizedWeightCache",
]

#: channel spec: None (per-tensor), one axis, or a tuple of kept axes
Axis = Union[None, int, Tuple[int, ...]]


class QTensor(NamedTuple):
    """A quantized tensor: ``value ~= q * 2.0**exp`` (per-channel)."""

    q: jnp.ndarray          # int8 / int16 / int32 payload
    exp: jnp.ndarray        # int32 per-channel exponents (broadcastable)
    axis: Axis = None       # channel axis (or axes) the exponents follow

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def shape(self):
        return self.q.shape


def _storage_dtype(bits: int):
    return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[bits]


@partial(jax.jit, static_argnames=("bits", "axis"))
def quantize_pow2(x, bits: int = 8, axis: Axis = None) -> QTensor:
    """Quantize to a power-of-two-scaled integer grid.

    exp is chosen per channel (or per tensor when axis is None) as the
    smallest e with ``amax / 2**e <= 2**(bits-1)``, so the payload fits
    the signed ``bits``-wide integer after round-to-nearest (the single
    rounding event — paper Eq. 6 applies per element).

    ``axis`` may be a tuple of KEPT axes (one exponent per index along
    each kept axis, reduced over the rest) — the per-(expert,
    out-channel) case for stacked MoE weights.
    """
    x = jnp.asarray(x, jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        keep = {a % x.ndim for a in (axis if isinstance(axis, tuple) else (axis,))}
        reduce_axes = tuple(i for i in range(x.ndim) if i not in keep)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    # e = ceil(log2(amax)) - (bits-1); amax==0 -> e=0
    safe = jnp.maximum(amax, jnp.float32(1e-30))
    e = jnp.ceil(jnp.log2(safe)).astype(jnp.int32) - (bits - 1)
    e = jnp.where(amax > 0, e, jnp.zeros_like(e, jnp.int32))
    scale = jnp.exp2(-e.astype(jnp.float32))  # 2**-e, exact for |e| < 127
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x * scale), -qmax - 1, qmax).astype(_storage_dtype(bits))
    return QTensor(q=q, exp=e, axis=axis)


def dequantize_pow2(qt: QTensor, dtype=jnp.float32):
    """Exact shift-only dequantization: ``q * 2.0**exp``."""
    return qt.q.astype(dtype) * jnp.exp2(qt.exp.astype(dtype))


def quantize_q16(x):
    """Fixed global Q16.16 (the paper's format) as a QTensor."""
    from repro.core.qformat import Q16_16, to_fixed

    q = to_fixed(x, Q16_16)
    return QTensor(q=q, exp=jnp.int32(-16), axis=None)


@partial(jax.jit, static_argnames=("bits",))
def compress_with_feedback(
    grad, residual, bits: int = 8
) -> Tuple[QTensor, jnp.ndarray]:
    """Error-feedback Q-format gradient compression.

    Quantizes ``grad + residual`` to ``bits`` with a per-tensor
    power-of-two scale and returns the new residual (the quantization
    error), so the compression error is *recirculated*, not lost —
    the standard EF-SGD trick, expressed in the paper's Q-format terms.
    """
    g = jnp.asarray(grad, jnp.float32) + residual
    qt = quantize_pow2(g, bits=bits, axis=None)
    new_residual = g - dequantize_pow2(qt)
    return qt, new_residual


# ---------------------------------------------------------------------------
# quantize-once weight store for the FAST path
# ---------------------------------------------------------------------------


class QuantizedWeightCache:
    """Weights quantized ONCE per ``(param_name, level)`` — never per call.

    The FAST model path used to requantize every weight matrix on every
    forward (``quantize_pow2`` inside ``dot_fast_int8``) — per token, in
    decode.  Weights are constant across serving steps, so this cache
    hoists the quantization to registration / level-switch time and the
    step functions consume pre-quantized int8 payloads.

    Coherence rules (documented in ROADMAP "Fused FAST path"):

    * entries are immutable once stored and keyed by ``(name, level)``,
      so level switches (``set_level``, scoped ``engine.at``, and the
      traced-index ``switched`` dispatch) never observe stale data —
      each rung reads its own entries;
    * *invalidation* (weights changed under the engine, e.g. a new
      checkpoint) must go through the two-phase barrier so no in-flight
      step sees a half-updated table — use
      :meth:`MathEngine.invalidate_weights`, which wraps
      :meth:`invalidate` in the quiesce -> swap protocol;
    * ``quantize_calls`` / ``hits`` are the counting hook the tests use
      to assert the decode loop performs ZERO weight quantizations.
      They are registry-backed metrics (``weight_quantize_total`` /
      ``weight_cache_hits_total`` — see
      :mod:`repro.runtime.telemetry`); the attributes remain as
      read-only delegating aliases.  A server re-homes them onto its
      own registry via :meth:`use_registry` so they show up in
      ``metrics_snapshot()`` / the Prometheus exposition.
    """

    def __init__(self, bits: int = 8, registry=None):
        from repro.runtime.telemetry import MetricsRegistry

        self.bits = bits
        self._store: dict = {}
        self._specs: dict = {}  # key -> (shape, dtype, axis) sanity record
        self._lock = threading.RLock()
        self._bind(registry if registry is not None else MetricsRegistry())

    def _bind(self, registry) -> None:
        self._registry = registry
        self._m_quantize = registry.counter(
            "weight_quantize_total", "weight quantizations performed (cache misses)"
        )
        self._m_hits = registry.counter(
            "weight_cache_hits_total", "pre-quantized weight reuses (cache hits)"
        )

    def use_registry(self, registry) -> None:
        """Re-home the counting hooks onto a shared registry (the
        serving telemetry's), carrying the current counts over."""
        with self._lock:
            q, h = self.quantize_calls, self.hits
            self._bind(registry)
            if q:
                self._m_quantize.inc(q)
            if h:
                self._m_hits.inc(h)

    @property
    def registry(self):
        return self._registry

    @property
    def quantize_calls(self) -> int:
        """Delegating alias for ``weight_quantize_total``."""
        return int(self._m_quantize.value())

    @property
    def hits(self) -> int:
        """Delegating alias for ``weight_cache_hits_total``."""
        return int(self._m_hits.value())

    def get(self, name: str, w, *, level: str = "q16_16", axis: Axis = None) -> QTensor:
        """The quantized form of ``w``, computed at most once per
        ``(name, level)``.  ``axis`` follows :func:`quantize_pow2`.

        A hit validates shape/dtype/axis against the stored entry and
        raises on mismatch (two different param trees sharing one cache
        under the same names).  A hit does NOT compare values — if the
        weights behind ``name`` changed, call
        :meth:`MathEngine.invalidate_weights` first; silently serving
        stale int8 payloads is exactly what the barrier-mediated
        invalidation contract exists to prevent.
        """
        key = (name, level)
        spec = (tuple(w.shape), str(getattr(w, "dtype", "?")), axis)
        with self._lock:
            hit = self._store.get(key)
            if hit is not None:
                if self._specs[key] != spec:
                    raise ValueError(
                        f"QuantizedWeightCache: {key} cached with spec "
                        f"{self._specs[key]}, requested {spec} — different "
                        f"param under the same name? invalidate first."
                    )
                self._m_hits.inc()
                return hit
        qt = quantize_pow2(w, bits=self.bits, axis=axis)
        with self._lock:
            self._m_quantize.inc()
            self._store.setdefault(key, qt)
            self._specs[key] = spec
            return self._store[key]

    def invalidate(self, name: Optional[str] = None) -> int:
        """Drop cached entries (all levels of ``name``; all entries when
        None).  Call through the engine's barrier-mediated
        ``invalidate_weights`` in live deployments."""
        with self._lock:
            if name is None:
                n = len(self._store)
                self._store.clear()
                self._specs.clear()
                return n
            victims = [k for k in self._store if k[0] == name]
            for k in victims:
                del self._store[k]
                del self._specs[k]
            return len(victims)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key) -> bool:
        with self._lock:
            if isinstance(key, tuple):
                return key in self._store
            return any(k[0] == key for k in self._store)
