"""The paper's primary contribution: a Dynamic Precision Math Engine.

C1  Q-format fixed-point core          -> qformat.py
C2  16-iteration CORDIC trigonometry   -> cordic.py
C3  deferred-shift tiled matmul        -> linalg.py (+ kernels/qmatmul)
C4  runtime precision switching        -> precision.py / barrier.py
      + dynamic arbitration (beyond paper) -> arbiter.py
Tensor-scale Q formats                 -> quantization.py
"""

from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.barrier import TwoPhaseBarrier, multihost_sync
from repro.core.cordic import (
    ATAN_TABLE_Q16,
    CORDIC_K_INV_Q16,
    HYPER_STAGES,
    ITER_Q24,
    angle_consts,
    atan2_q16,
    atan2_q24,
    cordic_atan2,
    cordic_atan2_24,
    cordic_div,
    cordic_exp,
    cordic_log,
    cordic_rotate_q16,
    cordic_sigmoid,
    cordic_sincos,
    cordic_sincos24,
    cordic_sincos_q16,
    cordic_sqrt,
    cordic_tanh,
    div_q16,
    exact_rope_phase_q16,
    exp_q16,
    hyper_gain_inverse,
    hyperbolic_schedule,
    log_q16,
    rope_inv_freq_q64,
    rope_tables_cordic,
    sigmoid_q16,
    sqrt_q16,
    tanh_q16,
)
from repro.core.linalg import (
    derive_tile_size,
    matmul_float,
    qmatmul_deferred,
    qmatmul_per_element,
)
from repro.core.precision import (
    MODE_ALIASES,
    OP_SET,
    MathEngine,
    Mode,
    PrecisionContext,
    PrecisionLevel,
    PrecisionPolicy,
    ladder,
    ladder_names,
    level,
    register_level,
    resolve_level,
)
from repro.core.qformat import (
    Q0_7,
    Q1_15,
    Q8_8,
    Q8_24,
    Q16_16,
    QFormat,
    from_fixed,
    q_add,
    q_add_sat,
    q_mul,
    q_mul_sat,
    q_sub,
    q_sub_sat,
    static_footprint_bytes,
    to_fixed,
)
from repro.core.quantization import (
    QTensor,
    compress_with_feedback,
    dequantize_pow2,
    quantize_pow2,
    quantize_q16,
)

__all__ = [k for k in dir() if not k.startswith("_")]
