"""Fixed-point linear algebra with deferred-shift accumulation (paper §3.3, §5.3).

Three implementations of Q16.16 matrix multiplication, mirroring the
paper's Listing 3 semantics:

* ``qmatmul_deferred``     — the paper's kernel: widened (64-bit, here
  paired-u32-limb) accumulation over each K-tile, ONE shift/rounding
  event per (output element, K-tile) instead of one per multiply
  (paper Eq. 18).  Tile size is a parameter; the paper derives b=32
  from the ESP32 SRAM geometry (Eq. 17: ``4 b**2 < 8192``); on TPU the
  analogous derivation lives in ``kernels/qmatmul`` (VMEM-sized
  BlockSpec tiles).
* ``qmatmul_per_element``  — the strawman the paper improves on:
  ``q_mul`` rounds after *every* product (b rounding events per inner
  product).  Used to demonstrate the error reduction.
* ``matmul_float``         — the IEEE 754 precise path (paper's
  ``f_matmul^F``).

All integer paths are bit-exactly validated against NumPy int64
oracles in ``tests/test_linalg.py``; the Pallas TPU kernel in
``kernels/qmatmul`` is the production version of the same contract.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.qformat import (
    add_64,
    add_64_pair,
    q_add_sat,
    q_mul,
    shift_right_64,
    widening_mul_i32,
)

__all__ = [
    "matmul_float",
    "qmatmul_per_element",
    "qmatmul_deferred",
    "derive_tile_size",
]


def derive_tile_size(workspace_bytes: int, element_bytes: int = 4, align: int = 1) -> int:
    """Paper Eq. 17 generalized: largest b with ``3 * b**2 * bytes`` in
    the working set (A, B, C tiles), rounded down to a power of two and
    then to ``align``.

    The paper uses a 2-tile budget (``4 b**2 < 8192`` => b < 45 => 32).
    On TPU we call this with the VMEM budget and align=128 (MXU lane
    width); see kernels/qmatmul/ops.py.
    """
    import math

    b = int(math.isqrt(workspace_bytes // (3 * element_bytes)))
    # round down to power of two
    b = 1 << (b.bit_length() - 1) if b > 0 else 1
    if align > 1:
        b = max((b // align) * align, align)
    return b


def matmul_float(a, b):
    """IEEE 754 precise path (fp32 accumulate)."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


@partial(jax.jit, static_argnames=("frac_bits", "rounding"))
def qmatmul_per_element(a_q, b_q, *, frac_bits: int = 16, rounding: bool = True):
    """Strawman: rounds after every scalar multiply (paper's 'b rounding
    events'). Accumulates the already-shifted Q products in int32."""
    a_q = jnp.asarray(a_q, jnp.int32)
    b_q = jnp.asarray(b_q, jnp.int32)
    prods = q_mul(
        a_q[:, :, None], b_q[None, :, :], frac_bits=frac_bits, rounding=rounding
    )  # (M, K, N) — fine at validation sizes
    return jnp.sum(prods, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("frac_bits", "rounding", "tile_k", "saturate"))
def qmatmul_deferred(
    a_q,
    b_q,
    *,
    frac_bits: int = 16,
    rounding: bool = True,
    tile_k: int = 32,
    saturate: bool = True,
):
    """Paper Listing 3: deferred-shift accumulation per K-tile.

    For each K-tile the full product is accumulated in a widened
    (paired-u32) accumulator and shifted ONCE (``C += acc >> 16``),
    exactly as the paper's inner loop.  Rounding events per output:
    ``ceil(K / tile_k)`` instead of ``K``.

    Implementation: ``lax.scan`` over K positions accumulating 64-bit
    limbs, with a tile boundary flush.  This is the *validation* path —
    the production TPU path (kernels/qmatmul) achieves the same
    contract with int8 operands and native int32 MXU accumulation.
    """
    a_q = jnp.asarray(a_q, jnp.int32)
    b_q = jnp.asarray(b_q, jnp.int32)
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2, (a_q.shape, b_q.shape)

    n_tiles = -(-K // tile_k)
    pad = n_tiles * tile_k - K
    if pad:
        a_q = jnp.pad(a_q, ((0, 0), (0, pad)))
        b_q = jnp.pad(b_q, ((0, pad), (0, 0)))

    # (n_tiles, tile_k, ...) views, scanned tile-by-tile
    a_t = a_q.T.reshape(n_tiles, tile_k, M)
    b_t = b_q.reshape(n_tiles, tile_k, N)

    round_add = jnp.uint32(1 << (frac_bits - 1)) if rounding else jnp.uint32(0)

    def tile_step(c_acc, tile):
        a_tile, b_tile = tile  # (tile_k, M), (tile_k, N)

        def k_step(carry, k_slice):
            hi, lo = carry
            a_k, b_k = k_slice  # (M,), (N,)
            p_hi, p_lo = widening_mul_i32(a_k[:, None], b_k[None, :])
            return add_64_pair(hi, lo, p_hi, p_lo), None

        zeros = jnp.zeros((M, N), jnp.uint32)
        (hi, lo), _ = jax.lax.scan(k_step, (zeros, zeros), (a_tile, b_tile))
        hi, lo = add_64(hi, lo, round_add)
        hi, lo = shift_right_64(hi, lo, frac_bits)
        tile_c = lo.astype(jnp.int32)  # assumes per-tile sum fits Q16.16 (paper §5.4)
        if saturate:
            c_acc = q_add_sat(c_acc, tile_c)
        else:
            c_acc = c_acc + tile_c
        return c_acc, None

    c0 = jnp.zeros((M, N), jnp.int32)
    c, _ = jax.lax.scan(tile_step, c0, (a_t, b_t))
    return c
