"""Runtime precision switching — the paper's principal contribution (C4, §4).

The paper keeps two parallel implementations of every operation in a
dispatch table ``D: F -> {f^Q, f^F}`` and swaps the whole table
atomically at O(1) cost, satisfying:

* R1 (API stability)      — callers never change;
* R2 (zero-cost abstraction) — no per-op dispatch overhead in steady state;
* R3 (O(1) switch latency) — pointer reassignment only;
* R4 (RTOS compatibility)  — a two-phase barrier guards the swap.

JAX adaptation: "function pointers" become **ahead-of-time compiled
executables**.  ``jax.jit(fn).lower(specs).compile()`` runs once per
(op, mode) at engine init; ``set_mode`` then swaps a dict reference —
it never re-traces or re-compiles, which is the R3 guarantee on this
substrate.  The two-phase FreeRTOS barrier becomes
``core/barrier.py``'s quiesce -> swap protocol (block on in-flight
device work, agree across hosts, then swap).
"""

from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax

from repro.core.barrier import TwoPhaseBarrier

__all__ = ["Mode", "OP_SET", "PrecisionContext", "MathEngine", "SwitchStats"]


class Mode(str, enum.Enum):
    """Paper §4.2: m in {FAST, PRECISE}."""

    FAST = "fast"          # Q-format integer path (f^Q)
    PRECISE = "precise"    # IEEE 754 path (f^F)


#: The paper's operation set F (Eq. 19) — six ops — extended with the
#: universal-CORDIC transcendental family (Walther modes: circular and
#: hyperbolic vectoring, hyperbolic rotation, linear division).  The
#: framework registers more (train_step, prefill_step, serve_step), but
#: these always exist.
OP_SET = (
    "mul", "add", "sub", "sin", "cos", "matmul",
    "atan2", "sqrt", "exp", "log", "tanh", "sigmoid",
)


class PrecisionContext:
    """The paper's MathContext: an immutable view of one dispatch table.

    A context is *frozen at construction*: once handed to application
    code it never mutates, so no operation can observe a half-switched
    table (the paper's 'no mixed-precision state' invariant).  Switching
    produces a NEW context; the engine swaps which one is current.
    """

    __slots__ = ("mode", "_table")

    def __init__(self, mode: Mode, table: Mapping[str, Callable]):
        object.__setattr__(self, "mode", mode)
        object.__setattr__(self, "_table", dict(table))

    def __setattr__(self, *_):  # pragma: no cover - guard
        raise AttributeError("PrecisionContext is immutable")

    def op(self, name: str) -> Callable:
        return self._table[name]

    def __getitem__(self, name: str) -> Callable:
        return self._table[name]

    def __contains__(self, name: str) -> bool:
        return name in self._table

    @property
    def ops(self) -> Tuple[str, ...]:
        return tuple(self._table)


@dataclass
class SwitchStats:
    count: int = 0
    last_latency_us: float = 0.0
    total_latency_us: float = 0.0
    history: list = field(default_factory=list)


class MathEngine:
    """Paper §4.4 public API: ``init(mode)``, ``setMode(mode)``, ``ctx()``.

    Ops are registered per mode, either as plain callables (host math,
    already-jitted functions) or as AOT-compiled executables built by
    :meth:`compile_op`.  ``set_mode`` runs the two-phase barrier and
    swaps one reference — measured in microseconds in
    ``benchmarks/bench_switch.py``, mirroring the paper's 8.09 us.
    """

    def __init__(self, mode: Mode = Mode.PRECISE, *, barrier: Optional[TwoPhaseBarrier] = None):
        self._impls: Dict[str, Dict[Mode, Callable]] = {}
        self._contexts: Dict[Mode, PrecisionContext] = {}
        self._mode = Mode(mode)
        self._ctx: Optional[PrecisionContext] = None
        self._barrier = barrier or TwoPhaseBarrier()
        self._lock = threading.Lock()
        self._inflight: Any = None  # last dispatched device result (quiesce target)
        self.switch_stats = SwitchStats()
        self._default_ops()

    # -- registration -----------------------------------------------------

    def _default_ops(self):
        """Install the paper's F set with both paths."""
        import jax.numpy as jnp

        from repro.core import cordic, linalg, qformat

        self.register("mul", fast=qformat.q_mul, precise=lambda a, b: a * b)
        self.register("add", fast=qformat.q_add, precise=lambda a, b: a + b)
        self.register("sub", fast=qformat.q_sub, precise=lambda a, b: a - b)
        self.register("sin", fast=lambda t: cordic.cordic_sincos(t)[0], precise=jnp.sin)
        self.register("cos", fast=lambda t: cordic.cordic_sincos(t)[1], precise=jnp.cos)
        self.register("matmul", fast=linalg.qmatmul_deferred, precise=linalg.matmul_float)
        # universal-CORDIC transcendental family (float boundaries on the
        # FAST path, same call signature in both modes — R1)
        self.register("atan2", fast=cordic.cordic_atan2, precise=jnp.arctan2)
        self.register("sqrt", fast=cordic.cordic_sqrt, precise=jnp.sqrt)
        self.register("exp", fast=cordic.cordic_exp, precise=jnp.exp)
        self.register("log", fast=cordic.cordic_log, precise=jnp.log)
        self.register("tanh", fast=cordic.cordic_tanh, precise=jnp.tanh)
        self.register("sigmoid", fast=cordic.cordic_sigmoid, precise=jax.nn.sigmoid)

    def register(self, name: str, *, fast: Callable, precise: Callable) -> None:
        self._impls[name] = {Mode.FAST: fast, Mode.PRECISE: precise}
        self._contexts.clear()  # contexts are rebuilt lazily

    def compile_op(self, name: str, impls: Dict[Mode, Callable], *example_args, **lower_kw) -> None:
        """AOT-compile both paths NOW so set_mode never compiles.

        ``example_args`` may be ShapeDtypeStructs (no allocation) or
        concrete arrays; ``lower_kw`` forwards in_shardings etc.
        """
        compiled = {}
        for mode, fn in impls.items():
            jitted = fn if isinstance(fn, jax.stages.Wrapped) else jax.jit(fn, **lower_kw)
            compiled[Mode(mode)] = jitted.lower(*example_args).compile()
        self._impls[name] = compiled
        self._contexts.clear()

    # -- paper API ---------------------------------------------------------

    def init(self, mode: Mode) -> "MathEngine":
        self._mode = Mode(mode)
        self._ctx = None
        return self

    def ctx(self) -> PrecisionContext:
        """Paper: MathEngine::ctx() — the active context."""
        if self._ctx is None or self._ctx.mode is not self._mode:
            self._ctx = self._context_for(self._mode)
        return self._ctx

    def _context_for(self, mode: Mode) -> PrecisionContext:
        if mode not in self._contexts:
            table = {name: impls[mode] for name, impls in self._impls.items() if mode in impls}
            self._contexts[mode] = PrecisionContext(mode, table)
        return self._contexts[mode]

    @property
    def mode(self) -> Mode:
        return self._mode

    def set_mode(self, mode: Mode) -> float:
        """Two-phase transition (paper §4.3.1). Returns latency in us.

        Phase 1 (quiesce): wait for the in-flight device step and reach
        cross-host agreement.  Phase 2 (swap): reassign the context
        reference.  Both contexts are prebuilt/precompiled, so phase 2
        is a single reference assignment — O(1), no retracing.
        """
        mode = Mode(mode)
        with self._lock:
            if mode is self._mode:
                return 0.0
            # Prebuild the target context OUTSIDE the timed swap (it is
            # cached after the first build; compile_op users pay nothing).
            target = self._context_for(mode)

            def swap():
                self._mode = mode
                self._ctx = target

            t0 = time.perf_counter()
            self._barrier.transition(inflight=self._inflight, swap_fn=swap)
            latency_us = (time.perf_counter() - t0) * 1e6
            s = self.switch_stats
            s.count += 1
            s.last_latency_us = latency_us
            s.total_latency_us += latency_us
            s.history.append((mode.value, latency_us))
            return latency_us

    # -- dispatch ----------------------------------------------------------

    def call(self, name: str, *args, **kw):
        """Dispatch through the active table, tracking in-flight work so
        the barrier can quiesce it (paper's 'worker completes its
        current operation')."""
        out = self.ctx().op(name)(*args, **kw)
        self._inflight = out
        return out
