"""Runtime precision ladder — the paper's C4 engine (§4), generalized.

The paper keeps TWO parallel implementations of every operation in a
dispatch table ``D: F -> {f^Q, f^F}`` and swaps the whole table
atomically at O(1) cost.  Transprecision platforms (Tagliavini et al.)
show the win comes from a *ladder* of formats chosen per operation, so
this module generalizes the binary FAST/PRECISE space to:

* **PrecisionLevel registry** — named levels, each binding a
  :class:`~repro.core.qformat.QFormat` (fixed-point) or a float dtype,
  ordered cheapest -> most precise::

      q8_8  <  q16_16  <  q8_24  <  f32

  ``Mode.FAST`` / ``Mode.PRECISE`` remain as compat aliases for
  ``q16_16`` / ``f32`` — every pre-ladder caller keeps working (R1).

* **Per-level op tables** — ops register implementations for any
  subset of levels; a level without its own implementation of an op
  resolves to the nearest *more precise* level that has one (then the
  nearest less precise), so every op is callable at every level.

* **PrecisionPolicy** — an op -> level override map on top of the
  engine's current level, so trig can run ``q8_24`` while matmul stays
  ``q16_16`` inside one context.

* **Scoped dispatch** — ``with engine.at(level_or_policy):`` switches
  through the two-phase barrier on entry and restores on exit;
  contexts are prebuilt and cached, so entry/exit stay O(1)
  reference swaps (R3).

* **jit-safe functional dispatch** — ``engine.switched(op)`` returns a
  branch table closed over every level's implementation, dispatched by
  a *traced* level index via ``jax.lax.switch``.  A jit-compiled step
  that takes the index as an argument changes levels with ZERO
  retraces — the R3 guarantee *inside* compiled code, where a Python
  reference swap cannot reach.

The paper's requirements, restated for the ladder:

* R1 (API stability)       — call sites never change across levels;
* R2 (zero-cost abstraction)— no per-op dispatch overhead in steady state;
* R3 (O(1) switch latency) — reference swap (host) / traced index (jit);
* R4 (RTOS compatibility)  — the two-phase barrier guards every swap.

JAX adaptation: "function pointers" become ahead-of-time compiled
executables.  ``jax.jit(fn).lower(specs).compile()`` runs once per
(op, level) at engine init; ``set_level`` then swaps a dict reference —
it never re-traces or re-compiles.  The two-phase FreeRTOS barrier
becomes ``core/barrier.py``'s quiesce -> swap protocol.
"""

from __future__ import annotations

import contextlib
import enum
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax

from repro.core.barrier import TwoPhaseBarrier
from repro.core.qformat import Q8_8, Q8_24, Q16_16, QFormat

__all__ = [
    "Mode",
    "OP_SET",
    "PrecisionLevel",
    "PrecisionPolicy",
    "PrecisionContext",
    "MathEngine",
    "SwitchStats",
    "register_level",
    "level",
    "ladder",
    "ladder_names",
    "resolve_level",
    "MODE_ALIASES",
]


class Mode(str, enum.Enum):
    """Paper §4.2: m in {FAST, PRECISE} — retained as compat aliases
    into the ladder (FAST = q16_16, PRECISE = f32)."""

    FAST = "fast"          # Q-format integer path (f^Q)
    PRECISE = "precise"    # IEEE 754 path (f^F)


#: The paper's operation set F (Eq. 19) — six ops — extended with the
#: universal-CORDIC transcendental family (Walther modes) and the
#: linear-vectoring division.  The framework registers more
#: (train_step, prefill_step, serve_step), but these always exist.
OP_SET = (
    "mul", "add", "sub", "sin", "cos", "matmul",
    "atan2", "sqrt", "exp", "log", "tanh", "sigmoid", "div",
)


# ---------------------------------------------------------------------------
# level registry (the ladder)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecisionLevel:
    """One rung of the ladder: a name bound to a number representation.

    ``qformat`` set  -> fixed-point level (f^Q family);
    ``qformat`` None -> float level with ``dtype`` (f^F family).
    """

    name: str
    qformat: Optional[QFormat] = None
    dtype: Any = None
    description: str = ""

    @property
    def is_fixed(self) -> bool:
        return self.qformat is not None

    @property
    def mode(self) -> Mode:
        """Compat projection onto the paper's binary space."""
        return Mode.FAST if self.is_fixed else Mode.PRECISE

    def __repr__(self):  # pragma: no cover - cosmetic
        rep = repr(self.qformat) if self.is_fixed else str(self.dtype)
        return f"PrecisionLevel({self.name}: {rep})"


#: insertion order IS the ladder order: cheapest -> most precise.
_LEVELS: Dict[str, PrecisionLevel] = {}

#: Mode -> level-name compat aliases (paper R1).
MODE_ALIASES: Dict[Mode, str] = {Mode.FAST: "q16_16", Mode.PRECISE: "f32"}


def register_level(lvl: PrecisionLevel, *, index: Optional[int] = None) -> PrecisionLevel:
    """Add (or replace) a named level.  ``index`` inserts mid-ladder;
    default appends at the precise end."""
    if lvl.name in _LEVELS:
        _LEVELS[lvl.name] = lvl
        return lvl
    if index is None:
        _LEVELS[lvl.name] = lvl
        return lvl
    items = list(_LEVELS.items())
    items.insert(index, (lvl.name, lvl))
    _LEVELS.clear()
    _LEVELS.update(items)
    return lvl


def level(name: str) -> PrecisionLevel:
    return _LEVELS[name]


def ladder() -> Tuple[PrecisionLevel, ...]:
    """All registered levels, cheapest first."""
    return tuple(_LEVELS.values())


def ladder_names() -> Tuple[str, ...]:
    return tuple(_LEVELS)


LevelSpec = Union["PrecisionLevel", Mode, str]


def resolve_level(spec: LevelSpec) -> PrecisionLevel:
    """Canonicalize a level spec: PrecisionLevel | Mode | level name |
    mode-value string ('fast'/'precise')."""
    if isinstance(spec, PrecisionLevel):
        return spec
    if isinstance(spec, Mode):
        return _LEVELS[MODE_ALIASES[spec]]
    if isinstance(spec, str):
        if spec in _LEVELS:
            return _LEVELS[spec]
        try:
            return _LEVELS[MODE_ALIASES[Mode(spec)]]
        except ValueError:
            raise KeyError(
                f"unknown precision level {spec!r}; have {ladder_names()}"
            ) from None
    raise TypeError(f"cannot resolve precision level from {spec!r}")


# the default ladder
register_level(PrecisionLevel("q8_8", qformat=Q8_8, description="int16 activations"))
register_level(PrecisionLevel("q16_16", qformat=Q16_16, description="paper Q16.16"))
register_level(PrecisionLevel("q8_24", qformat=Q8_24, description="high-precision angle"))
register_level(PrecisionLevel("f32", dtype="float32", description="IEEE 754 binary32"))


# ---------------------------------------------------------------------------
# per-op policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecisionPolicy:
    """op -> level overrides on top of a default level.

    ``default`` None means "the engine's current level" — the policy
    then only pins the listed ops.  Hashable (context-cache key), so
    ``per_op`` is normalized to a sorted tuple at construction.
    """

    default: Optional[str] = None
    per_op: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        if self.default is not None:
            object.__setattr__(self, "default", resolve_level(self.default).name)
        if isinstance(self.per_op, Mapping):
            items = self.per_op.items()
        else:
            items = self.per_op
        norm = tuple(sorted((op, resolve_level(lv).name) for op, lv in items))
        object.__setattr__(self, "per_op", norm)

    def level_for(self, op: str, fallback: str) -> str:
        for name, lv in self.per_op:
            if name == op:
                return lv
        return self.default if self.default is not None else fallback

    def __contains__(self, op: str) -> bool:
        return any(name == op for name, _ in self.per_op)


# ---------------------------------------------------------------------------
# immutable context
# ---------------------------------------------------------------------------


class PrecisionContext:
    """The paper's MathContext: an immutable view of one dispatch table.

    A context is *frozen at construction*: once handed to application
    code it never mutates, so no operation can observe a half-switched
    table (the paper's 'no mixed-precision state' invariant).  Switching
    produces a NEW context; the engine swaps which one is current.
    """

    __slots__ = ("level", "mode", "policy", "_table")

    def __init__(self, lvl: LevelSpec, table: Mapping[str, Callable],
                 policy: Optional[PrecisionPolicy] = None):
        lvl = resolve_level(lvl)
        object.__setattr__(self, "level", lvl)
        object.__setattr__(self, "mode", lvl.mode)
        object.__setattr__(self, "policy", policy)
        object.__setattr__(self, "_table", dict(table))

    def __setattr__(self, *_):  # pragma: no cover - guard
        raise AttributeError("PrecisionContext is immutable")

    def op(self, name: str) -> Callable:
        return self._table[name]

    def __getitem__(self, name: str) -> Callable:
        return self._table[name]

    def __contains__(self, name: str) -> bool:
        return name in self._table

    @property
    def ops(self) -> Tuple[str, ...]:
        return tuple(self._table)


@dataclass
class SwitchStats:
    count: int = 0
    last_latency_us: float = 0.0
    total_latency_us: float = 0.0
    history: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class MathEngine:
    """Paper §4.4 public API, ladder edition.

    Compat surface (unchanged): ``init(mode)``, ``set_mode(mode)``,
    ``ctx()``, ``call(op, *args)``, ``register(op, fast=..., precise=...)``.

    Ladder surface: ``set_level(level)``, ``with engine.at(level):``,
    ``set_policy(policy)``, ``register(op, q8_24=..., f32=...)``,
    ``switched(op)`` + ``level_index()`` for jit-safe dispatch.

    Ops are registered per level, either as plain callables (host math,
    already-jitted functions) or as AOT-compiled executables built by
    :meth:`compile_op`.  Every switch runs the two-phase barrier and
    swaps one reference — measured in microseconds in
    ``benchmarks/bench_paper_tables.py``, mirroring the paper's 8.09 us.
    """

    def __init__(
        self,
        level: LevelSpec = Mode.PRECISE,
        *,
        barrier: Optional[TwoPhaseBarrier] = None,
        policy: Optional[PrecisionPolicy] = None,
    ):
        self._impls: Dict[str, Dict[str, Callable]] = {}
        self._contexts: Dict[Any, PrecisionContext] = {}
        self._level = resolve_level(level)
        self._policy = policy
        self._ctx: Optional[PrecisionContext] = None
        self._barrier = barrier or TwoPhaseBarrier()
        self._lock = threading.RLock()
        self._inflight: Any = None  # last dispatched device result (quiesce target)
        self._weight_cache = None
        self.switch_stats = SwitchStats()
        self._default_ops()

    # -- registration -----------------------------------------------------

    def _default_ops(self):
        """Install the paper's F set across the default ladder."""
        import jax.numpy as jnp

        from repro.core import cordic, linalg, qformat

        self.register(
            "mul",
            q8_8=partial(qformat.q_mul, frac_bits=8),
            q16_16=qformat.q_mul,
            q8_24=partial(qformat.q_mul, frac_bits=24),
            f32=lambda a, b: a * b,
        )
        self.register("add", q16_16=qformat.q_add, f32=lambda a, b: a + b)
        self.register("sub", q16_16=qformat.q_sub, f32=lambda a, b: a - b)
        self.register(
            "sin",
            q16_16=lambda t: cordic.cordic_sincos(t)[0],
            q8_24=lambda t: cordic.cordic_sincos24(t)[0],
            f32=jnp.sin,
        )
        self.register(
            "cos",
            q16_16=lambda t: cordic.cordic_sincos(t)[1],
            q8_24=lambda t: cordic.cordic_sincos24(t)[1],
            f32=jnp.cos,
        )
        self.register("matmul", q16_16=linalg.qmatmul_deferred, f32=linalg.matmul_float)
        # universal-CORDIC transcendental family (float boundaries on the
        # fixed-point paths, same call signature at every level — R1)
        self.register(
            "atan2",
            q16_16=cordic.cordic_atan2,
            q8_24=cordic.cordic_atan2_24,
            f32=jnp.arctan2,
        )
        self.register("sqrt", q16_16=cordic.cordic_sqrt, f32=jnp.sqrt)
        self.register("exp", q16_16=cordic.cordic_exp, f32=jnp.exp)
        self.register("log", q16_16=cordic.cordic_log, f32=jnp.log)
        self.register("tanh", q16_16=cordic.cordic_tanh, f32=jnp.tanh)
        self.register("sigmoid", q16_16=cordic.cordic_sigmoid, f32=jax.nn.sigmoid)
        self.register("div", q16_16=cordic.cordic_div, f32=lambda a, b: a / b)

    def register(
        self,
        name: str,
        *,
        fast: Optional[Callable] = None,
        precise: Optional[Callable] = None,
        **level_impls: Callable,
    ) -> None:
        """Register per-level implementations of an op.

        Compat kwargs: ``fast`` -> q16_16, ``precise`` -> f32.  Any
        level name is accepted as a keyword (``q8_24=fn``).  The op's
        previous registration is replaced wholesale.
        """
        table: Dict[str, Callable] = {}
        if fast is not None:
            table[MODE_ALIASES[Mode.FAST]] = fast
        if precise is not None:
            table[MODE_ALIASES[Mode.PRECISE]] = precise
        for lv, fn in level_impls.items():
            table[resolve_level(lv).name] = fn
        if not table:
            raise ValueError(f"register({name!r}): no implementations given")
        self._impls[name] = table
        self._contexts.clear()  # contexts are rebuilt lazily
        self._ctx = None

    def compile_op(
        self, name: str, impls: Dict[LevelSpec, Callable], *example_args, **lower_kw
    ) -> None:
        """AOT-compile every path NOW so set_level never compiles.

        ``impls`` keys may be Modes or level names.  ``example_args``
        may be ShapeDtypeStructs (no allocation) or concrete arrays;
        ``lower_kw`` forwards in_shardings etc.
        """
        compiled = {}
        for lv, fn in impls.items():
            jitted = fn if isinstance(fn, jax.stages.Wrapped) else jax.jit(fn, **lower_kw)
            compiled[resolve_level(lv).name] = jitted.lower(*example_args).compile()
        self._impls[name] = compiled
        self._contexts.clear()
        self._ctx = None

    # -- level/impl resolution ---------------------------------------------

    def _impl_for(self, name: str, level_name: str) -> Callable:
        """The op's implementation at a level, with ladder fallback:
        exact level, else nearest MORE precise level with an
        implementation (precision never silently degrades), else
        nearest less precise."""
        impls = self._impls[name]
        if level_name in impls:
            return impls[level_name]
        names = ladder_names()
        r = names.index(level_name)
        for nm in names[r + 1:]:
            if nm in impls:
                return impls[nm]
        for nm in reversed(names[:r]):
            if nm in impls:
                return impls[nm]
        raise KeyError(f"op {name!r} has no implementation reachable from level {level_name!r}")

    def _context_for(self, level_name: str, policy: Optional[PrecisionPolicy]) -> PrecisionContext:
        key = (level_name, policy)
        if key not in self._contexts:
            table = {
                name: self._impl_for(
                    name,
                    policy.level_for(name, level_name) if policy is not None else level_name,
                )
                for name in self._impls
            }
            self._contexts[key] = PrecisionContext(level(level_name), table, policy)
        return self._contexts[key]

    # -- paper API ---------------------------------------------------------

    def init(self, level: LevelSpec) -> "MathEngine":
        self._level = resolve_level(level)
        self._ctx = None
        return self

    def ctx(self) -> PrecisionContext:
        """Paper: MathEngine::ctx() — the active context."""
        if self._ctx is None or (self._ctx.level is not self._level or self._ctx.policy != self._policy):
            self._ctx = self._context_for(self._level.name, self._policy)
        return self._ctx

    @property
    def mode(self) -> Mode:
        """Compat: the binary projection of the current level."""
        return self._level.mode

    @property
    def level(self) -> PrecisionLevel:
        return self._level

    @property
    def policy(self) -> Optional[PrecisionPolicy]:
        return self._policy

    def set_mode(self, mode: LevelSpec) -> float:
        """Compat alias for :meth:`set_level` (paper §4.4 setMode)."""
        return self.set_level(mode)

    def set_level(self, spec: LevelSpec) -> float:
        """Two-phase transition (paper §4.3.1). Returns latency in us.

        Phase 1 (quiesce): wait for the in-flight device step and reach
        cross-host agreement.  Phase 2 (swap): reassign the context
        reference.  Contexts are prebuilt/precompiled and cached, so
        phase 2 is a single reference assignment — O(1), no retracing.
        """
        target_level = resolve_level(spec)
        with self._lock:
            if target_level is self._level:
                return 0.0
            # Prebuild the target context OUTSIDE the timed swap (it is
            # cached after the first build; compile_op users pay nothing).
            target = self._context_for(target_level.name, self._policy)
            return self._swap(lambda: (
                setattr(self, "_level", target_level),
                setattr(self, "_ctx", target),
            ), tag=target_level.name)

    def set_policy(self, policy: Optional[PrecisionPolicy]) -> float:
        """Swap the per-op policy through the same two-phase barrier.
        Structurally equal policies are a free no-op (PrecisionPolicy
        normalizes to sorted tuples, so == is the table-identity test)."""
        with self._lock:
            if policy == self._policy:
                return 0.0
            target = self._context_for(self._level.name, policy)
            return self._swap(lambda: (
                setattr(self, "_policy", policy),
                setattr(self, "_ctx", target),
            ), tag=f"policy:{policy!r}")

    # -- quantized-weight cache --------------------------------------------

    @property
    def weight_cache(self):
        """The engine's quantize-once weight store (lazily created).

        Entries are keyed per ``(param, level)``, so ``set_level`` /
        ``engine.at`` / jit-switch dispatch stay coherent without any
        invalidation — each rung reads its own immutable entries.  Only
        a *weight update* invalidates, and that goes through the
        two-phase barrier (:meth:`invalidate_weights`).
        """
        with self._lock:
            if self._weight_cache is None:
                from repro.core.quantization import QuantizedWeightCache

                self._weight_cache = QuantizedWeightCache()
            return self._weight_cache

    def invalidate_weights(self, name: Optional[str] = None) -> float:
        """Drop cached quantized weights through the two-phase barrier
        (paper §4.3.1 applied to the weight table): quiesce the
        in-flight step, reach cross-host agreement, THEN clear — so no
        step ever mixes old float weights with stale int8 payloads.
        Returns the transition latency in us."""
        cache = self.weight_cache
        with self._lock:
            return self._swap(lambda: cache.invalidate(name), tag=f"weights:{name}")

    def _swap(self, swap_fn: Callable[[], Any], tag: str) -> float:
        t0 = time.perf_counter()
        self._barrier.transition(inflight=self._inflight, swap_fn=swap_fn)
        latency_us = (time.perf_counter() - t0) * 1e6
        s = self.switch_stats
        s.count += 1
        s.last_latency_us = latency_us
        s.total_latency_us += latency_us
        s.history.append((tag, latency_us))
        return latency_us

    @contextlib.contextmanager
    def at(self, spec: Union[LevelSpec, PrecisionPolicy]):
        """Scoped dispatch: ``with engine.at("q8_24"): ...``.

        Accepts a level (switches the whole table) or a
        :class:`PrecisionPolicy` (overrides per-op levels).  Entry and
        exit each run the two-phase barrier; nesting restores the
        outer level/policy on exit.  Contexts are cached, so repeated
        entry is the O(1) reference swap (R3).
        """
        if isinstance(spec, PrecisionPolicy):
            prev = self._policy
            self.set_policy(spec)
            try:
                yield self
            finally:
                self.set_policy(prev)
        else:
            prev = self._level
            self.set_level(spec)
            try:
                yield self
            finally:
                self.set_level(prev)

    # -- dispatch ----------------------------------------------------------

    def call(self, name: str, *args, **kw):
        """Dispatch through the active table, tracking in-flight work so
        the barrier can quiesce it (paper's 'worker completes its
        current operation')."""
        out = self.ctx().op(name)(*args, **kw)
        self._inflight = out
        return out

    # -- jit-safe functional dispatch --------------------------------------

    def switched(
        self, name: str, levels: Optional[Sequence[LevelSpec]] = None
    ) -> Tuple[Callable, Tuple[str, ...]]:
        """Build the jit-safe branch table for one op.

        Returns ``(dispatch, level_names)`` where
        ``dispatch(level_idx, *args)`` selects the implementation with
        ``jax.lax.switch`` — ``level_idx`` may be a TRACED int32, so a
        jit-compiled step switches levels with zero retraces.  All
        branches are traced once at first compilation; thereafter the
        level is data, not code.
        """
        names = (
            tuple(resolve_level(lv).name for lv in levels)
            if levels is not None
            else ladder_names()
        )
        branches = [self._impl_for(name, nm) for nm in names]

        def dispatch(level_idx, *args):
            return jax.lax.switch(level_idx, branches, *args)

        return dispatch, names

    def level_index(self, levels: Optional[Sequence[str]] = None) -> int:
        """Index of the current level inside ``levels`` (default: the
        full ladder) — feed this as the traced argument of a
        :meth:`switched` dispatch.  A current level absent from
        ``levels`` maps to the nearest more precise entry (else the
        most precise available), mirroring :meth:`_impl_for`."""
        names = tuple(resolve_level(lv).name for lv in levels) if levels else ladder_names()
        if self._level.name in names:
            return names.index(self._level.name)
        full = ladder_names()
        rank = full.index(self._level.name)
        candidates = [(full.index(nm), i) for i, nm in enumerate(names)]
        above = [i for r, i in candidates if r > rank]
        if above:
            return min(above, key=lambda i: full.index(names[i]))
        return max(range(len(names)), key=lambda i: full.index(names[i]))
