"""Cell builder: (architecture x input shape x mesh x mode) -> a jitted
step function + ShapeDtypeStruct inputs + shardings.

This is the single source of truth used by the multi-pod dry-run, the
roofline benchmarks and the real train/serve drivers, so what we
compile in the dry-run IS the production step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (
    RuleSet,
    batch_pspec,
    serve_rules,
    train_rules,
    tree_shardings,
)
from repro.models import init_caches, param_specs
from repro.models.config import ModelConfig
from repro.models.layers import Spec
from repro.models.model import decode_step, prefill_step, train_loss
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, opt_state_specs

__all__ = ["SHAPES", "ShapeCell", "SkipCell", "build_cell", "cell_ids"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str       # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train", 4096, 256),
    "prefill_32k": ShapeCell("prefill", 32768, 32),
    "decode_32k": ShapeCell("decode", 32768, 128),
    "long_500k": ShapeCell("decode", 524288, 1),
}


class SkipCell(Exception):
    """Raised when a cell is skipped by assignment rules (with reason)."""


def cell_ids():
    from repro.configs import ARCH_IDS

    return [(a, s) for a in ARCH_IDS for s in SHAPES]


def _sds(spec: Spec, dtype=None):
    return jax.ShapeDtypeStruct(spec.shape, dtype or spec.dtype)


def _specs_to_sds(tree, dtype=None):
    return jax.tree.map(
        lambda s: _sds(s, dtype), tree, is_leaf=lambda x: isinstance(x, Spec)
    )


def _make_constrain(rs: RuleSet, batch: int, seq: int):
    mesh = rs.mesh
    bspec = batch_pspec(rs, batch, extra_dims=0)
    batch_names = bspec[0]
    seq_axis = rs.rules.get("seq")
    model_size = mesh.shape.get("model", 1)

    def constrain(x, kind):
        if kind == "residual" and x.ndim == 3:
            s_name = (
                seq_axis
                if (seq_axis and x.shape[1] % model_size == 0 and x.shape[1] > 1)
                else None
            )
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(batch_names, s_name, None))
            )
        if kind == "moe4d" and x.ndim == 4:
            # (B, E, C, d): keep batch sharded through gather/expert-mm
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(batch_names, None, None, None))
            )
        if kind == "moe3d" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(batch_names, None, None))
            )
        if kind == "heads4d" and x.ndim == 4:
            # TP layout through the mixer: heads over model, full seq per
            # device (the seq<->heads reshard happens here, once per layer)
            h_name = "model" if x.shape[2] % model_size == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(batch_names, None, h_name, None))
            )
        return x

    return constrain


def _cache_shardings(caches, cfg: ModelConfig, rs: RuleSet, batch: int):
    mesh = rs.mesh
    bnames = batch_pspec(rs, batch, extra_dims=0)[0]
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)

    def seq_name(L: int, kv_sharded: bool = True):
        """Sequence sharding of caches, two roles:

        * context parallelism: batch axis idle (B=1 long-context) ->
          seq over 'data';
        * kv-head fallback: kv heads not divisible by 'model' (kv=8 or
          4 vs 16) -> seq over 'model' instead, so the cache still
          shards 16-ways (GSPMD turns the softmax over the sharded
          length into tiny max/sum all-reduces).
        """
        axes = []
        if bnames is None:
            axes.append("data")
        if not kv_sharded:
            axes.append("model")
        if not axes:
            return None
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if L % size != 0 or L < size:
            # retry with 'data' alone
            if "data" in axes and L % data == 0 and L >= data:
                axes = ["data"]
            else:
                return None
        return axes[0] if len(axes) == 1 else tuple(axes)

    def leaf_spec(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = x.ndim  # leading axis is n_periods
        if name in ("k", "v"):           # (Pd, B, L, kv, hd)
            kv_ok = x.shape[3] % model == 0
            kv = "model" if kv_ok else None
            return P(None, bnames, seq_name(x.shape[2], kv_ok), kv, None)
        if name in ("k_exp", "v_exp"):   # (Pd, B, L, KV)
            kv_ok = x.shape[3] % model == 0
            return P(None, bnames, seq_name(x.shape[2], kv_ok),
                     "model" if kv_ok else None)
        if name == "pos":                # (Pd, B, L)
            # must shard exactly like k/v's L dim; kv divisibility comes
            # from the config, not this leaf
            kv_ok = (cfg.n_kv_heads % model == 0) if cfg.n_kv_heads else True
            if cfg.mla is not None:
                kv_ok = False
            return P(None, bnames, seq_name(x.shape[2], kv_ok))
        if name in ("ckv", "krope"):     # (Pd, B, L, r) — MLA latent: no head dim
            return P(None, bnames, seq_name(x.shape[2], False), None)
        if name == "state":              # (Pd, B, nh, ds, hd)
            nh = "model" if x.shape[2] % model == 0 else None
            return P(None, bnames, nh, None, None)
        if name == "conv":               # (Pd, B, K-1, conv_dim)
            cd = "model" if x.shape[3] % model == 0 else None
            return P(None, bnames, None, cd)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, leaf_spec(p, x)), caches
    )


def _check_long_context(cfg: ModelConfig, shape_id: str):
    if shape_id == "long_500k" and not cfg.is_subquadratic:
        raise SkipCell(
            f"{cfg.name}: long_500k skipped — pure full-attention architecture "
            "(assignment: run only for SSM/hybrid/sliding-window archs; see DESIGN.md §4)"
        )


#: microbatch counts for activation-heavy train cells (grad accumulation)
GRAD_ACCUM = {
    "mixtral-8x22b": 8,
    "jamba-v0.1-52b": 16,
    "command-r-35b": 2,
    "minicpm3-4b": 2,
    "granite-moe-3b-a800m": 2,
    "mamba2-1.3b": 2,
}


def build_cell(
    arch: str,
    shape_id: str,
    mesh: Mesh,
    mode: str = "precise",
    *,
    fsdp: bool = True,
    remat: bool = True,
    opt_cfg: Optional[AdamWConfig] = None,
    grad_accum: Optional[int] = None,
    sharding: str = "default",
):
    """Returns (jitted_fn, example_args (SDS pytree), meta dict).

    ``jitted_fn.lower(*example_args)`` is the dry-run; calling it with
    real arrays is the production step.
    """
    cfg = get_config(arch)
    cell = SHAPES[shape_id]
    _check_long_context(cfg, shape_id)

    if cell.kind == "train":
        accum = grad_accum if grad_accum is not None else GRAD_ACCUM.get(cfg.name, 1)
        return _build_train(
            cfg, cell, mesh, mode, fsdp=fsdp, remat=remat, opt_cfg=opt_cfg,
            grad_accum=accum, sharding=sharding,
        )
    if cell.kind == "prefill":
        return _build_prefill(cfg, cell, mesh, mode)
    return _build_decode(cfg, cell, mesh, mode)


# ---------------------------------------------------------------------------


def _batch_specs(cfg: ModelConfig, cell: ShapeCell, rs: RuleSet):
    B, S = cell.batch, cell.seq
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs = {"tokens": toks, "labels": toks}
    shard = {
        "tokens": NamedSharding(rs.mesh, batch_pspec(rs, B)),
        "labels": NamedSharding(rs.mesh, batch_pspec(rs, B)),
    }
    if cfg.modality_stub:
        specs["extra_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.stub_prefix_len, cfg.d_model), jnp.bfloat16
        )
        shard["extra_embeds"] = NamedSharding(rs.mesh, batch_pspec(rs, B, extra_dims=2))
    return specs, shard


def _build_train(cfg, cell, mesh, mode, *, fsdp, remat, opt_cfg, grad_accum: int = 1,
                 sharding: str = "default"):
    rs = train_rules(mesh, fsdp=fsdp, pure_fsdp=(sharding == "pure_fsdp"))
    opt_cfg = opt_cfg or AdamWConfig()

    p_specs = param_specs(cfg)
    p_shard = tree_shardings(p_specs, rs)
    p_sds = _specs_to_sds(p_specs)
    o_specs = opt_state_specs(p_specs)
    o_shard = tree_shardings(o_specs, rs)
    o_sds = _specs_to_sds(o_specs)
    b_sds, b_shard = _batch_specs(cfg, cell, rs)
    constrain = _make_constrain(rs, cell.batch, cell.seq)

    grad_fn = jax.value_and_grad(
        lambda p, b: train_loss(p, b, cfg, mode=mode, constrain=constrain, remat=remat),
        has_aux=True,
    )

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # gradient accumulation: scan over microbatches — activation
            # memory is one microbatch's worth (EXPERIMENTS.md §Perf P4)
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(acc_step, (g0, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )
    meta = {
        "arch": cfg.name, "shape": f"{cell.kind}", "mode": mode,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "batch": cell.batch, "seq": cell.seq, "kind": "train",
        "dropped_rules": rs.dropped,
    }
    return jitted, (p_sds, o_sds, b_sds), meta


def _serve_ruleset(cfg, mesh):
    model = mesh.shape.get("model", 1)
    wbytes_dev = 2 * cfg.param_count() / model  # bf16, model-sharded only
    return serve_rules(mesh, weight_fsdp=wbytes_dev > 5 * 2**30)


def _build_prefill(cfg, cell, mesh, mode):
    rs = _serve_ruleset(cfg, mesh)
    p_specs = param_specs(cfg)
    p_shard = tree_shardings(p_specs, rs)
    p_sds = _specs_to_sds(p_specs, dtype=jnp.bfloat16)
    b_sds, b_shard = _batch_specs(cfg, cell, rs)
    constrain = _make_constrain(rs, cell.batch, cell.seq)

    caches = jax.eval_shape(lambda: init_caches(cfg, cell.batch, cell.seq,
                                                quantized=(mode == "fast")))
    c_shard = _cache_shardings(caches, cfg, rs, cell.batch)
    c_sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches)

    extra = (b_sds.get("extra_embeds"),) if cfg.modality_stub else ()

    def step(params, tokens, caches, *extra_embeds):
        ee = extra_embeds[0] if extra_embeds else None
        return prefill_step(params, tokens, caches, cfg, mode=mode, constrain=constrain,
                            extra_embeds=ee)

    in_sh = (p_shard, b_shard["tokens"], c_shard) + (
        (b_shard["extra_embeds"],) if cfg.modality_stub else ()
    )
    jitted = jax.jit(
        step,
        in_shardings=in_sh,
        out_shardings=(None, c_shard),
        donate_argnums=(2,),
    )
    args = (p_sds, b_sds["tokens"], c_sds) + extra
    meta = {
        "arch": cfg.name, "mode": mode, "batch": cell.batch, "seq": cell.seq,
        "kind": "prefill", "params": cfg.param_count(),
        "active_params": cfg.active_param_count(), "dropped_rules": rs.dropped,
    }
    return jitted, args, meta


def _build_decode(cfg, cell, mesh, mode):
    rs = _serve_ruleset(cfg, mesh)
    p_specs = param_specs(cfg)
    p_shard = tree_shardings(p_specs, rs)
    p_sds = _specs_to_sds(p_specs, dtype=jnp.bfloat16)

    B = cell.batch
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = NamedSharding(rs.mesh, batch_pspec(rs, B))
    pos_sh = NamedSharding(rs.mesh, batch_pspec(rs, B, extra_dims=0))

    caches = jax.eval_shape(lambda: init_caches(cfg, B, cell.seq,
                                                quantized=(mode == "fast")))
    c_shard = _cache_shardings(caches, cfg, rs, B)
    c_sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches)
    constrain = _make_constrain(rs, B, 1)

    def step(params, token, position, caches):
        return decode_step(params, token, position, caches, cfg, mode=mode, constrain=constrain)

    jitted = jax.jit(
        step,
        in_shardings=(p_shard, tok_sh, pos_sh, c_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(3,),
    )
    meta = {
        "arch": cfg.name, "mode": mode, "batch": B, "seq": cell.seq,
        "kind": "decode", "params": cfg.param_count(),
        "active_params": cfg.active_param_count(), "dropped_rules": rs.dropped,
    }
    return jitted, (p_sds, tok_sds, pos_sds, c_sds), meta
