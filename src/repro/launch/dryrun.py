import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e) + roofline extraction (g).

For every (architecture x input shape x mesh) cell: build the
production step via launch/steps.py, ``.lower().compile()`` it against
ShapeDtypeStruct inputs (no allocation), then record:

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline
* collective bytes                — parsed from the optimized HLO text
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute), since cost_analysis does not expose them
* the three roofline terms in seconds + the dominant bottleneck.

The 512-device host-platform override above MUST precede any other
import (jax locks the device count at first init).  Never set it
globally: smoke tests and benches see the real single CPU device.
"""

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import HW, make_mesh_by_name
from repro.launch.steps import SHAPES, SkipCell, build_cell


def model_flops(meta: dict) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n = meta["active_params"]
    if meta["kind"] == "train":
        return 6.0 * n * meta["batch"] * meta["seq"]
    if meta["kind"] == "prefill":
        return 2.0 * n * meta["batch"] * meta["seq"]
    return 2.0 * n * meta["batch"]  # decode: one token per sequence


def roofline(meta, costs, n_chips, mode: str) -> dict:
    """costs: trip-count-aware HloCosts (per-device)."""
    flops_dev = float(costs.flops)
    bytes_dev = float(costs.bytes)
    coll_dev = float(costs.total_collective_bytes)
    peak = HW.PEAK_INT8_OPS if mode == "fast" else HW.PEAK_BF16_FLOPS
    terms = {
        "compute_s": flops_dev / peak,
        "memory_s": bytes_dev / HW.HBM_BW,
        "collective_s": coll_dev / HW.ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(meta)
    hlo_global = flops_dev * n_chips
    bound_s = max(terms.values())
    useful_ratio = mf / hlo_global if hlo_global else 0.0
    # fraction of roofline: time the useful math would take at peak vs
    # the dominant-term time the compiled program needs
    ideal_s = mf / (n_chips * peak)
    hints = {
        "compute_s": "cut redundant HLO FLOPs (remat waste, masked attention chunks) or switch the matmuls to the int8 fast path (2x peak)",
        "memory_s": "increase reuse (larger fused blocks), quantize weights/KV-cache, or shard the dominant resident tensor further",
        "collective_s": "overlap collectives with compute (latency-hiding), compress gradients (Q-format int8), or re-map the sharding to cut resharding",
    }
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "useful_flop_ratio": useful_ratio,
        "roofline_fraction": (ideal_s / bound_s) if bound_s > 0 else 0.0,
        "hint": hints[dominant],
    }


def run_cell(arch, shape_id, mesh_name, mode="precise", *, fsdp=True, remat=True,
             sharding="default", grad_accum=None, verbose=True):
    mesh = make_mesh_by_name(mesh_name)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name, "mode": mode,
           "chips": n_chips, "fsdp": fsdp, "remat": remat, "sharding": sharding}
    try:
        jitted, args, meta = build_cell(arch, shape_id, mesh, mode, fsdp=fsdp, remat=remat,
                                        sharding=sharding, grad_accum=grad_accum)
    except SkipCell as e:
        rec.update(status="skip", reason=str(e))
        if verbose:
            print(f"[skip] {arch} x {shape_id} x {mesh_name}: {e}")
        return rec

    with mesh:
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo)  # trip-count-aware (see hlo_analysis.py)
    rl = roofline(meta, costs, n_chips, mode)

    mem_rec = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_rec[f] = getattr(mem, f, None)

    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        meta={k: v for k, v in meta.items() if k != "dropped_rules"},
        dropped_rules=[list(map(str, d)) for d in meta.get("dropped_rules", [])],
        memory=mem_rec,
        # raw HloCostAnalysis aggregates (while bodies counted ONCE —
        # kept for reference; the roofline uses the trip-aware numbers)
        xla_cost_analysis={
            k: cost.get(k) for k in ("flops", "bytes accessed", "transcendentals") if k in cost
        },
        hlo_costs=costs.as_dict(),
        roofline=rl,
    )
    if verbose:
        print(f"[ok] {arch} x {shape_id} x {mesh_name} ({mode}) "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
        print(f"     memory_analysis: {mem_rec}")
        print(f"     hlo (trip-aware): flops={costs.flops:.3e} bytes={costs.bytes:.3e} "
              f"collective={costs.total_collective_bytes:.3e} B in "
              f"{costs.total_collective_count:.0f} ops")
        print(f"     roofline: compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s "
              f"collective={rl['collective_s']:.4f}s -> dominant {rl['dominant']}, "
              f"fraction {rl['roofline_fraction']:.3f}, useful-FLOP ratio "
              f"{rl['useful_flop_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="precise", choices=["precise", "fast"])
    ap.add_argument("--all", action="store_true", help="every arch x shape for --mesh")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--sharding", default="default", choices=["default", "pure_fsdp"])
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import ARCH_IDS

        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape_id in cells:
        rec = run_cell(
            arch, shape_id, args.mesh, args.mode,
            fsdp=not args.no_fsdp, remat=not args.no_remat,
            sharding=args.sharding, grad_accum=args.grad_accum,
        )
        tag = f"-{args.tag}" if args.tag else ""
        name = f"{arch}-{shape_id}-{args.mesh}-{args.mode}{tag}.json"
        (out_dir / name).write_text(json.dumps(rec, indent=2, default=str))
        print(f"     -> {out_dir / name}")


if __name__ == "__main__":
    main()
