"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only dryrun.py sets the 512-device host-platform override).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_by_name", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_by_name(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name == "host":  # whatever this process actually has (tests)
        n = len(jax.devices())
        return jax.make_mesh((1, n), ("data", "model"))
    raise ValueError(name)


class HW:
    """TPU v5e per-chip roofline constants (assignment §ROOFLINE)."""

    PEAK_BF16_FLOPS = 197e12       # FLOP/s
    PEAK_INT8_OPS = 394e12         # int8 MXU ~2x bf16
    HBM_BW = 819e9                 # bytes/s
    ICI_BW = 50e9                  # bytes/s per link
    HBM_BYTES = 16 * 2**30
