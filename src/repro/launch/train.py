"""Production training driver.

Single-host CPU smoke:
    PYTHONPATH=src python -m repro.launch.train --arch deepseek_7b --smoke --steps 20

Production (TPU pod; same code, real mesh):
    python -m repro.launch.train --arch mixtral_8x22b --shape train_4k \
        --mesh single --steps 10000 --mode fast --arbiter

On a real multi-host deployment jax.distributed.initialize() is called
first (env-driven); this container has one CPU device, so --smoke uses
the family-preserving reduced config and the local device.
"""

from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", default="precise", choices=["precise", "fast"])
    ap.add_argument("--arbiter", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="reduced config, local device")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.smoke or args.mesh is None:
        from repro.configs import smoke
        from repro.core.precision import Mode
        from repro.runtime.train_loop import Trainer, TrainerConfig

        cfg = smoke(args.arch)
        tcfg = TrainerConfig(
            total_steps=args.steps,
            ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=args.ckpt_dir,
            start_mode=Mode(args.mode),
            use_arbiter=args.arbiter,
        )
        out = Trainer(cfg, tcfg).run()
        print(f"final loss {out['final_loss']:.4f} after {args.steps} steps "
              f"({out['switches']} precision switches)")
        return

    # production path: build the sharded cell and run it step by step
    if jax.process_count() == 1 and len(jax.devices()) < 256:
        raise SystemExit(
            "production mesh requested but this host has "
            f"{len(jax.devices())} devices; use --smoke here, or launch on the pod "
            "(the multi-pod configuration is validated by repro.launch.dryrun)"
        )
    from repro.launch.mesh import make_mesh_by_name
    from repro.launch.steps import build_cell
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.configs import get_config
    from repro.models import init_params
    from repro.optim.adamw import init_opt_state
    import jax.numpy as jnp

    mesh = make_mesh_by_name(args.mesh)
    jitted, sds, meta = build_cell(args.arch, args.shape, mesh, args.mode)
    cfg = get_config(args.arch)
    with mesh:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=4096, global_batch=256))
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt, metrics = jitted(params, opt, batch)
            if step % 10 == 0:
                print(f"step {step}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
