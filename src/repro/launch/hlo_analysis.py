"""Trip-count-aware HLO cost extraction.

``HloCostAnalysis`` (what ``compiled.cost_analysis()`` wraps) counts
every ``while`` body ONCE — useless for scan-heavy programs where >95%
of the work sits inside layer/chunk loops.  XLA, however, stamps every
while with ``backend_config={"known_trip_count":{"n":...}}``; this
module parses the optimized HLO text, walks the computation graph and
multiplies nested loop bodies by their trip counts, producing:

* ``flops``       — 2 * numel(out) * K summed over every ``dot``
                    (contracted size K resolved from operand shapes)
* ``bytes``       — operand + result bytes of every materializing op
                    (fusion parameters/outputs ~ XLA's bytes-accessed
                    model = a good HBM-traffic proxy post-fusion)
* ``collectives`` — per-type output bytes and op counts
                    (all-gather / all-reduce / reduce-scatter /
                    all-to-all / collective-permute)

All values are per-device (the SPMD module is the per-device program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+(?:\{[\d,]*\})?)\s+([\w\-]+)\((.*)$"
)
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:body|to_apply)=%?([\w\.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

# ops that do not move HBM bytes
_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    return [
        (dt, [int(d) for d in dims.split(",")] if dims else [])
        for dt, dims in _SHAPE_RE.findall(shape_str)
        if dt in _DTYPE_BYTES
    ]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attributes


@dataclass
class _Comp:
    name: str
    ops: List[_Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def total_collective_count(self) -> float:
        return sum(self.collective_counts.values())

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            self.flops * k,
            self.bytes * k,
            {o: b * k for o, b in self.collective_bytes.items()},
            {o: c * k for o, c in self.collective_counts.items()},
        )

    def add(self, other: "HloCosts") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for o, b in other.collective_bytes.items():
            self.collective_bytes[o] = self.collective_bytes.get(o, 0.0) + b
        for o, c in other.collective_counts.items():
            self.collective_counts[o] = self.collective_counts.get(o, 0.0) + c

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "total_collective_count": self.total_collective_count,
        }


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    current: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.strip()
        if current is None:
            m = _HEADER_RE.match(line)
            if m and line.endswith("{"):
                current = _Comp(m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if line == "}":
            comps[current.name] = current
            current = None
            continue
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        op = _Op(name, shape, opcode, rest)
        current.ops.append(op)
        current.shapes[name] = shape
    return comps, entry


def _dot_flops(op: _Op, comp: _Comp) -> float:
    out_elems = 0
    for _dt, dims in _shape_dims(op.shape):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    # contracted size from the lhs operand shape
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    k = 1
    if mm and operands:
        lhs_shape = comp.shapes.get(operands[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)
            if dims:
                lhs_dims = dims[0][1]
                for idx in (int(i) for i in mm.group(1).split(",") if i):
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _op_bytes(op: _Op, comp: _Comp) -> float:
    total = float(_shape_bytes(op.shape))
    operand_str = op.rest.split("), ")[0]
    for name in _OPERAND_RE.findall(operand_str):
        s = comp.shapes.get(name)
        if s:
            total += _shape_bytes(s)
    return total


def analyze_hlo(text: str) -> HloCosts:
    comps, entry = _parse_computations(text)
    memo: Dict[str, HloCosts] = {}
    # computations referenced by fusion ops are internal (no HBM traffic)
    fusion_callees = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                mm = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if mm:
                    fusion_callees.add(mm.group(1))

    def visit(name: str) -> HloCosts:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        total = HloCosts()
        if comp is None:
            memo[name] = total
            return total
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trips = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trips = int(mt.group(1))
                mb = _CALL_ATTR_RE.search(op.rest)
                if mb:
                    total.add(visit(mb.group(1)).scaled(trips))
                mc = _COND_ATTR_RE.search(op.rest)
                if mc:
                    total.add(visit(mc.group(1)).scaled(trips))
                continue
            if oc in ("call", "conditional", "async-start"):
                for callee in _CALL_ATTR_RE.findall(op.rest):
                    total.add(visit(callee))
                # conditional: branch_computations={%a, %b}
                mbr = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if mbr:
                    for callee in _OPERAND_RE.findall(mbr.group(1)):
                        total.add(visit(callee))
                continue
            base = oc.replace("-start", "") if oc.endswith("-start") else oc
            if base in COLLECTIVE_OPS:
                b = float(_shape_bytes(op.shape))
                total.collective_bytes[base] = total.collective_bytes.get(base, 0.0) + b
                total.collective_counts[base] = total.collective_counts.get(base, 0.0) + 1
                total.bytes += b
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
                total.bytes += _op_bytes(op, comp)
                continue
            if oc in _FREE_OPS or oc.endswith("-done"):
                continue
            total.bytes += _op_bytes(op, comp)
        memo[name] = total
        return total

    if entry is None:
        return HloCosts()
    # ENTRY only; computations reached via fusion are intentionally not
    # visited (their traffic is the fusion op's operands/results).
    return visit(entry)
