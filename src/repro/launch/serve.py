"""Serving driver: batched generation with runtime precision modes.

Smoke (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --continuous
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b \
        --continuous --paged --prefix-sharing \
        --metrics-out metrics.prom --trace-out trace.json

``--continuous`` runs the continuous-batching engine (per-request
precision via ``--levels``) on a mixed-length/mixed-budget workload;
the default runs the static lock-step ``BatchedServer``.  Both routes
build ONE :class:`~repro.runtime.config.ServingConfig` — assembled by
:func:`serving_config_from_args`, which is what
tests/test_serve_cli.py pins: every cache/telemetry flag must round-trip
into a validated config.  ``--continuous --speculative`` serves every
request through ladder-speculative decoding (draft at ``--draft-level``,
verify at f32 — output identical to vanilla f32 greedy; watch
``spec_rounds`` / ``spec_accepted`` in the printed stats).  ``--paged``
switches the cache pool to fixed-size pages + block tables with chunked
prefill (``--prefill-chunk`` tokens per fixed-shape segment); add
``--prefix-sharing`` to share full prefix pages between requests
(full-context attention models only).

Telemetry outputs (see docs/observability.md): ``--metrics-out FILE``
writes the Prometheus text exposition after serving; ``--trace-out
FILE`` writes the Chrome ``trace_event`` JSON (open in Perfetto).
Either flag turns the profiler tier on for the run.
"""

from __future__ import annotations

import argparse

import jax

MAX_LEN = 128


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="precise", choices=["precise", "fast"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine instead of the static server")
    ap.add_argument("--slots", type=int, default=2,
                    help="device batch slots for --continuous")
    ap.add_argument("--levels", default=None,
                    help="comma list of per-request ladder levels for --continuous "
                         "(cycled over requests; e.g. 'q16_16,f32')")
    ap.add_argument("--speculative", action="store_true",
                    help="with --continuous: serve every request in "
                         "ladder-speculative mode (draft at --draft-level, "
                         "verify at f32 — output identical to vanilla f32)")
    ap.add_argument("--draft-level", default="q16_16", choices=["q8_8", "q16_16"],
                    help="draft rung for --speculative")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per speculative round")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: paged cache pool + chunked prefill")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache rows per page for --paged")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill segment length (default: page size)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="with --paged: share full prefix pages across requests")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="total pages in the full-length pool (default: sized "
                         "to the slot count)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the Prometheus metrics exposition here after "
                         "serving (enables telemetry)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the Chrome trace_event JSON here after serving "
                         "(enables telemetry + tracing; open in Perfetto)")
    return ap


def serving_config_from_args(args):
    """The one flags -> :class:`ServingConfig` mapping (validated by
    the config's own ``__post_init__``)."""
    from repro.runtime.config import ServingConfig
    from repro.runtime.speculative import SpeculativeConfig
    from repro.runtime.telemetry import TelemetryConfig

    spec = (
        SpeculativeConfig(k=args.spec_k, draft_level=args.draft_level,
                          max_len=MAX_LEN)
        if args.speculative else None
    )
    telemetry = TelemetryConfig(
        enabled=bool(args.metrics_out or args.trace_out),
        trace=bool(args.trace_out),
    )
    return ServingConfig(
        n_slots=args.slots, max_len=MAX_LEN, speculative=spec,
        cache="paged" if args.paged else "contiguous",
        page_size=args.page_size, prefill_chunk=args.prefill_chunk,
        prefix_sharing=args.prefix_sharing, n_pages=args.n_pages,
        telemetry=telemetry,
    )


def _write_outputs(srv, args) -> None:
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(srv.render_prometheus())
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        srv.telemetry.write_trace(args.trace_out)
        print(f"trace   -> {args.trace_out}")


def main(argv=None):
    args = build_parser().parse_args(argv)

    from repro.configs import smoke
    from repro.core.precision import Mode
    from repro.models import init_params
    from repro.runtime.config import ServingConfig
    from repro.runtime.scheduler import Request
    from repro.runtime.serve import BatchedServer, ContinuousBatchingServer

    cfg = smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4, 5], [10, 11, 12], [7, 7, 7, 7], [3, 1, 4, 1, 5, 9]]

    if args.continuous:
        srv = ContinuousBatchingServer(cfg, params, serving_config_from_args(args))
        levels = args.levels.split(",") if args.levels else [None]
        reqs = [
            Request(rid=srv.next_rid(), prompt=p, max_new=args.max_new + 4 * (i % 2),
                    level=levels[i % len(levels)],
                    speculative=args.speculative)
            for i, p in enumerate(prompts)
        ]
        fins = srv.serve(reqs)
        for r in reqs:
            f = fins[r.rid]
            print(f"req{r.rid} [{r.level or 'default'}] ({f.reason}): {f.tokens}")
        print(f"stats: {srv.stats}")
        if args.paged:
            print(f"pages: {srv.cache_ops.report()}")
        _write_outputs(srv, args)
        return

    from repro.runtime.telemetry import TelemetryConfig

    srv = BatchedServer(
        cfg, params,
        ServingConfig(n_slots=4, max_len=MAX_LEN, max_new=args.max_new,
                      default_level=Mode(args.mode),
                      telemetry=TelemetryConfig(
                          enabled=bool(args.metrics_out or args.trace_out),
                          trace=bool(args.trace_out))),
    )
    for i, seq in enumerate(srv.generate(prompts)):
        print(f"req{i}: {seq}")
    if args.metrics_out or args.trace_out:
        print("note: --metrics-out/--trace-out apply to --continuous; "
              "static-server metrics are limited to the weight cache")
        _write_outputs(srv, args)


if __name__ == "__main__":
    main()
