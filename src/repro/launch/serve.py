"""Serving driver: batched generation with runtime precision modes.

Smoke (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --smoke
"""

from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="precise", choices=["precise", "fast"])
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import smoke
    from repro.core.precision import Mode
    from repro.models import init_params
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(
        cfg, params,
        ServerConfig(max_batch=4, max_len=128, max_new=args.max_new,
                     start_mode=Mode(args.mode)),
    )
    prompts = [[1, 2, 3, 4, 5], [10, 11, 12], [7, 7, 7, 7], [3, 1, 4, 1, 5, 9]]
    for i, seq in enumerate(srv.generate(prompts)):
        print(f"req{i}: {seq}")


if __name__ == "__main__":
    main()
