"""Serving driver: batched generation with runtime precision modes.

Smoke (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --continuous
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b \
        --continuous --paged --prefix-sharing

``--continuous`` runs the continuous-batching engine (per-request
precision via ``--levels``) on a mixed-length/mixed-budget workload;
the default runs the static lock-step ``BatchedServer``.  Both routes
build ONE :class:`~repro.runtime.config.ServingConfig`.
``--continuous --speculative`` serves every request through
ladder-speculative decoding (draft at ``--draft-level``, verify at f32
— output identical to vanilla f32 greedy; watch ``spec_rounds`` /
``spec_accepted`` in the printed stats).  ``--paged`` switches the
cache pool to fixed-size pages + block tables with chunked prefill
(``--prefill-chunk`` tokens per fixed-shape segment); add
``--prefix-sharing`` to share full prefix pages between requests
(full-context attention models only).
"""

from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="precise", choices=["precise", "fast"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine instead of the static server")
    ap.add_argument("--slots", type=int, default=2,
                    help="device batch slots for --continuous")
    ap.add_argument("--levels", default=None,
                    help="comma list of per-request ladder levels for --continuous "
                         "(cycled over requests; e.g. 'q16_16,f32')")
    ap.add_argument("--speculative", action="store_true",
                    help="with --continuous: serve every request in "
                         "ladder-speculative mode (draft at --draft-level, "
                         "verify at f32 — output identical to vanilla f32)")
    ap.add_argument("--draft-level", default="q16_16", choices=["q8_8", "q16_16"],
                    help="draft rung for --speculative")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per speculative round")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: paged cache pool + chunked prefill")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache rows per page for --paged")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill segment length (default: page size)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="with --paged: share full prefix pages across requests")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="total pages in the full-length pool (default: sized "
                         "to the slot count)")
    args = ap.parse_args()

    from repro.configs import smoke
    from repro.core.precision import Mode
    from repro.models import init_params
    from repro.runtime.config import ServingConfig
    from repro.runtime.scheduler import Request
    from repro.runtime.serve import BatchedServer, ContinuousBatchingServer

    cfg = smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4, 5], [10, 11, 12], [7, 7, 7, 7], [3, 1, 4, 1, 5, 9]]

    if args.continuous:
        from repro.runtime.speculative import SpeculativeConfig

        spec = (
            SpeculativeConfig(k=args.spec_k, draft_level=args.draft_level,
                              max_len=128)
            if args.speculative else None
        )
        srv = ContinuousBatchingServer(
            cfg, params,
            ServingConfig(
                n_slots=args.slots, max_len=128, speculative=spec,
                cache="paged" if args.paged else "contiguous",
                page_size=args.page_size, prefill_chunk=args.prefill_chunk,
                prefix_sharing=args.prefix_sharing, n_pages=args.n_pages,
            ),
        )
        levels = args.levels.split(",") if args.levels else [None]
        reqs = [
            Request(rid=srv.next_rid(), prompt=p, max_new=args.max_new + 4 * (i % 2),
                    level=levels[i % len(levels)],
                    speculative=args.speculative)
            for i, p in enumerate(prompts)
        ]
        fins = srv.serve(reqs)
        for r in reqs:
            f = fins[r.rid]
            print(f"req{r.rid} [{r.level or 'default'}] ({f.reason}): {f.tokens}")
        print(f"stats: {srv.stats}")
        if args.paged:
            print(f"pages: {srv.cache_ops.report()}")
        return

    srv = BatchedServer(
        cfg, params,
        ServingConfig(n_slots=4, max_len=128, max_new=args.max_new,
                      default_level=Mode(args.mode)),
    )
    for i, seq in enumerate(srv.generate(prompts)):
        print(f"req{i}: {seq}")


if __name__ == "__main__":
    main()
