"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400, llama-architecture.  [arXiv:2401.02954; hf]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    d_model=4096,
    n_layers=30,
    period=(LayerSpec(kind="attn", window=None, ffn="mlp"),),
    vocab=102400,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    rope_base=10000.0,
    max_seq=32768,
)
