"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448,
multi-head latent attention (MLA).  [hf:openbmb/MiniCPM3-4B; hf]

MLA ranks follow the model card family (q_lora 768, kv_lora 256,
nope 64 / rope 32 / v 64 per head); the latent cache is what decode
stores — (kv_rank + rope) per token, ~11x smaller than GQA kv=40.
"""

from repro.models.config import LayerSpec, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    d_model=2560,
    n_layers=62,
    period=(LayerSpec(kind="mla", window=None, ffn="mlp"),),
    vocab=73448,
    n_heads=40,
    n_kv_heads=40,
    head_dim=0,
    d_ff=6400,
    mla=MLAConfig(
        q_lora_rank=768, kv_lora_rank=256,
        qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
    ),
    rope_base=10000.0,
    max_seq=32768,
)
