"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000, local(4096)/global alternating, logit softcaps
(attn 50, final 30), tied embeddings.  [arXiv:2408.00118; hf]

Period = (local SWA, global full) x 13.  head_dim=256 (Gemma decouples
head width from d_model / n_heads).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    d_model=2304,
    n_layers=26,
    period=(
        LayerSpec(kind="attn", window=4096, ffn="mlp"),
        LayerSpec(kind="attn", window=None, ffn="mlp"),
    ),
    vocab=256000,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    rope_base=10000.0,
    max_seq=32768,
)
