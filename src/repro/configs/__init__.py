"""Assigned architecture configs (``--arch <id>``) + the paper's own
engine config.  Each module exposes ``CONFIG`` built from the exact
public spec; ``get_config(name)`` resolves ids; ``smoke(name)`` returns
the family-preserving reduced config for CPU smoke tests."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, smoke_config

ARCH_IDS = (
    "granite_moe_3b_a800m",
    "mixtral_8x22b",
    "phi3_vision_4_2b",
    "deepseek_7b",
    "minicpm3_4b",
    "command_r_35b",
    "gemma2_2b",
    "jamba_v01_52b",
    "mamba2_1_3b",
    "musicgen_large",
)

_ALIASES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x22b": "mixtral_8x22b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "deepseek-7b": "deepseek_7b",
    "minicpm3-4b": "minicpm3_4b",
    "command-r-35b": "command_r_35b",
    "gemma2-2b": "gemma2_2b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-1.3b": "mamba2_1_3b",
    "musicgen-large": "musicgen_large",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke(name: str) -> ModelConfig:
    return smoke_config(get_config(name))


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
