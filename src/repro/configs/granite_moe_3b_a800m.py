"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite; hf]

The assigned spec string self-contradicts ("MoE 40e top-8 — 32 experts
top-8"); we follow the primary token (40 experts), matching the
3b-a800m family name.  See DESIGN.md §2.
"""

from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    d_model=1536,
    n_layers=32,
    period=(LayerSpec(kind="attn", window=None, ffn="moe"),),
    vocab=49155,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe=MoEConfig(num_experts=40, top_k=8, dispatch_chunk=1024),
    rope_base=10000.0,
    max_seq=32768,
)
