"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 1:7 interleave.
[arXiv:2403.19887; hf]

Period of 8: position 0 is attention, 1-7 are mamba; MoE replaces the
MLP on odd positions (every-2 pattern).  Jamba ships Mamba-1 layers
(d_state=16); we use the SSD block with matching state size — same
state capacity, TPU-friendly dual form (DESIGN.md §2).
"""

from repro.models.config import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_period = tuple(
    LayerSpec(
        kind=("attn" if i == 0 else "mamba"),
        window=None,
        ffn=("moe" if i % 2 == 1 else "mlp"),
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096,
    n_layers=32,
    period=_period,
    vocab=65536,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe=MoEConfig(num_experts=16, top_k=2, dispatch_chunk=2048),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=128),
    rope_base=10000.0,
    max_seq=524288,
)
