"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064.  [hf:microsoft/Phi-3-vision-128k-instruct; hf]

Backbone only: the CLIP vision frontend is a stub — input_specs()
supplies precomputed patch embeddings added to the first
``stub_prefix_len`` positions (assignment's [vlm] rule).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    d_model=3072,
    n_layers=32,
    period=(LayerSpec(kind="attn", window=None, ffn="mlp"),),
    vocab=32064,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    modality_stub="vision",
    stub_prefix_len=576,     # 24x24 CLIP patch grid
    rope_base=10000.0,
    max_seq=131072,
)
