"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]

All layers SWA => sub-quadratic decode; long_500k runs with a
window-bounded rolling cache (DESIGN.md §4).
"""

from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    d_model=6144,
    n_layers=56,
    period=(LayerSpec(kind="attn", window=4096, ffn="moe"),),
    vocab=32768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    moe=MoEConfig(num_experts=8, top_k=2, dispatch_chunk=2048),
    rope_base=1000000.0,
    max_seq=524288,
)
