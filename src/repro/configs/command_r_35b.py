"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, no-bias, tied embeddings.  [hf:CohereForAI; unverified]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    d_model=8192,
    n_layers=40,
    period=(LayerSpec(kind="attn", window=None, ffn="mlp"),),
    vocab=256000,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    tie_embeddings=True,
    rope_base=8000000.0,
    max_seq=131072,
)
