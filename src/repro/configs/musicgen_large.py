"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048, decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a stub — input_specs() supplies
precomputed frame embeddings ([audio] rule).  Positional encoding uses
the framework's rotary path (MusicGen's sinusoidal embeddings are a
frontend detail; noted in DESIGN.md).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    d_model=2048,
    n_layers=48,
    period=(LayerSpec(kind="attn", window=None, ffn="mlp"),),
    vocab=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    modality_stub="audio",
    stub_prefix_len=256,
    rope_base=10000.0,
    max_seq=32768,
)
