"""mamba2-1.3b [ssm] — 48L d_model=2048, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]

The paper's CORDIC/trig module is inapplicable (no rotary phases); the
Q-format matmul path still covers all projections
(DESIGN.md §Arch-applicability).
"""

from repro.models.config import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    d_model=2048,
    n_layers=48,
    period=(LayerSpec(kind="mamba", window=None, ffn="none"),),
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    max_seq=1048576,
)
