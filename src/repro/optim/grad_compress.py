"""Q-format gradient compression for data-parallel reduction
(paper C1 + §8.6 "distributed multi-node linear algebra").

A plain f32 ring all-reduce moves ~2 x size(f32) per device.  The
compressed reducer moves int8 Q-format payloads instead:

    flatten -> [pmax exponent] -> quantize int8 (shared pow2 scale)
      -> all_to_all (each device owns 1/n of the vector)
      -> local int32 sum (exact: n <= 2**24 summands of |q| <= 127)
      -> requantize int8 -> all_gather

Wire bytes: 2 x size(int8) = size(f32)/2 per device — a 4x reduction
versus the f32 ring — visible in the dry-run's collective term (s8
all-to-all / all-gather ops in the HLO).  Error feedback recirculates
the quantization error so SGD convergence is preserved (EF-SGD); the
error-feedback state lives in the optimizer state pytree.

Use inside ``jax.shard_map`` over the DP axes (see
make_dp_train_step); the model axes stay automatic.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map

__all__ = ["compressed_mean", "make_dp_train_step"]


def _compress_leaf(g, r, axis_name: str, n_dev: int, bits: int):
    """One leaf: returns (mean_gradient, new_residual)."""
    g32 = g.astype(jnp.float32) + r
    flat = g32.reshape(-1)
    n = flat.shape[0]
    pad = -n % n_dev
    if pad:
        flat = jnp.pad(flat, (0, pad))

    # shared power-of-two exponent (paper C1: shift-only rescale)
    amax = jnp.max(jnp.abs(flat))
    amax = jax.lax.pmax(amax, axis_name)
    e = jnp.where(
        amax > 0,
        jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30))).astype(jnp.int32) - (bits - 1),
        0,
    )
    scale = jnp.exp2(-e.astype(jnp.float32))
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(flat * scale), -qmax - 1, qmax).astype(jnp.int8)

    # error feedback BEFORE the wire (local quantization error)
    deq_local = q.astype(jnp.float32) * jnp.exp2(e.astype(jnp.float32))
    new_r = (flat - deq_local)[:n].reshape(g.shape)

    # reduce: int8 all_to_all -> exact int32 local sum -> int8 all_gather
    chunks = q.reshape(n_dev, -1)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0, concat_axis=0, tiled=False)
    local_sum = jnp.sum(recv.astype(jnp.int32), axis=0)  # exact
    # requantize the sum (one extra rounding event, bounded by 2**e2).
    # local_sum is in units of the 2**e grid, so the requantization
    # shift is RELATIVE: e2 - e = ceil(log2(n_dev)) — a pure bit shift,
    # the paper's deferred single-shift correction on the wire.
    shift = int(np.ceil(np.log2(n_dev)))
    e2 = e + shift
    q2 = jnp.clip(
        jnp.round(local_sum.astype(jnp.float32) * jnp.float32(2.0 ** -shift)),
        -qmax - 1, qmax,
    ).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis_name, axis=0, tiled=True)
    mean = gathered.astype(jnp.float32) * (jnp.exp2(e2.astype(jnp.float32)) / n_dev)
    return mean[:n].reshape(g.shape), new_r


def compressed_mean(grads, residuals, axis_name: str, n_dev: int, bits: int = 8):
    """Tree-wise compressed DP mean with error feedback.

    grads/residuals: matching pytrees (residuals f32, zeros at init).
    Returns (mean_grads, new_residuals).
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [_compress_leaf(g, r, axis_name, n_dev, bits) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])


def make_dp_train_step(cfg, opt_cfg, mesh, *, compress_bits: Optional[int] = 8, mode="precise"):
    """Data-parallel train step with explicit (optionally compressed)
    gradient reduction, shard_map'd over the 'data' axis.

    Returns step(params, opt_state, residuals, batch) ->
    (params, opt_state, residuals, metrics).  Parameters replicated
    across 'data' (pure DP); combine with TP by leaving other mesh
    axes automatic.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models.model import train_loss
    from repro.optim.adamw import adamw_update

    n_dev = mesh.shape["data"]

    def local_step(params, opt_state, residuals, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, mode=mode), has_aux=True
        )(params)
        if compress_bits is not None:
            grads, residuals = compressed_mean(grads, residuals, "data", n_dev, compress_bits)
        else:
            grads = jax.lax.pmean(grads, "data")
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=jax.lax.pmean(loss, "data"), **om)
        return params, opt_state, residuals, metrics

    rep = P()
    bspec = {"tokens": P("data"), "labels": P("data")}
    return jax.jit(
        shard_map(
            local_step,
            mesh=mesh,
            in_specs=(rep, rep, rep, bspec),
            out_specs=(rep, rep, rep, rep),
            check_vma=False,
        )
    )
