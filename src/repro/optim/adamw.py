"""AdamW with decoupled weight decay, global-norm clipping and a
warmup-cosine schedule — pure pytree functions (no optax dependency).

State layout mirrors parameters (m, v per leaf), so the sharding rules
that place parameters place optimizer state identically (FSDP shards
the full 12-byte/param train state).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "opt_state_specs", "adamw_update", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray  # scalar int32


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def opt_state_specs(param_specs_tree):
    """Spec pytree for the optimizer state (same axes as params, f32)."""
    from repro.models.layers import Spec

    f32 = lambda s: Spec(s.shape, s.axes, jnp.float32, "zeros", s.scale)
    return OptState(
        m=jax.tree.map(f32, param_specs_tree, is_leaf=lambda x: isinstance(x, Spec)),
        v=jax.tree.map(f32, param_specs_tree, is_leaf=lambda x: isinstance(x, Spec)),
        step=Spec((), (), jnp.int32, "zeros"),
    )


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        p2 = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}
