"""Paged cache blocks behind a unified ``CacheOps`` surface.

The contiguous serving pool reserves ``max_len`` cache rows per slot
for the slot's whole lifetime — a 5-token lookup holds the same KV
memory as a 250-token generation.  This module replaces that with
vLLM-style paging:

* **pages** — every position-indexed cache tree (attention k/v/pos,
  MLA ckv/krope/pos) is stored as stacked ``(n_periods, n_pages,
  page_size, ...)`` leaves.  Page 0 is the reserved ZERO page (pristine
  fill: payload 0, position sentinel -1) that unallocated block-table
  entries point at, so a gathered view of an empty slot is exactly the
  freshly-reset contiguous cache.
* **block tables** — a host-side ``(n_slots, blocks_per_slot)`` int32
  table per page GROUP (caches sharing a length ``L`` share one
  free-list allocator and one table; sliding-window layers form their
  own small group of ``window // page_size`` blocks).  The device
  mirror is an ordinary jit argument: table CONTENT changes never
  retrace.
* **gather/scatter adapters** — ``device_view`` gathers pages into the
  exact logical ``(n_periods, B, L, ...)`` layout ``decode_step`` /
  ``segment_step`` already consume (bit-identical values), and
  ``commit_rows`` scatters back ONLY the rows a step wrote (decode: 1
  row; speculative verify: k+1 rows whose rejected entries carry the
  rolled-back ``before`` bits — page-granular restore stays bit-exact).
  Cumulative SSM state is O(1) per slot and stays slot-contiguous
  inside the same state tree.
* **prefix sharing** — full pages are keyed by a SHA-256 chain over
  the token prefix (page ``i`` hashes tokens ``[0, (i+1)*page_size)``
  through its predecessor's digest); matching requests attach the
  cached pages by reference (refcounted, copy-on-write) and prefill
  only their tail.  Restricted to models whose caches are ALL
  full-context position-indexed: a sliding-window buffer's content at a
  boundary depends on when prefill passed it, and SSM state is
  cumulative — neither is a pure function of the token prefix, so
  neither can be shared by content hash.

The api_redesign part: the old ad-hoc helper sprawl
(``write_cache_slot`` / ``reset_cache_slot`` /
``reset_{attn,mla,ssm}_cache_slot``) is consolidated behind the
:class:`CacheOps` protocol (``alloc / write / read / reset / snapshot /
restore``), implemented by :class:`ContiguousCacheOps` (proven
bit-identical to the old helpers by tests/test_cachepool.py) and
:class:`PagedCachePool`.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache_layout, init_caches, reset_cache_slot, write_cache_slot
from repro.models.config import ModelConfig

__all__ = [
    "PageAllocator",
    "PrefixCache",
    "token_hash_chain",
    "CacheOps",
    "ContiguousCacheOps",
    "PagedCachePool",
]


# ---------------------------------------------------------------------------
# page allocator (pure host state)
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list page allocator with reference counts.

    Page 0 is the reserved zero page: never allocated, refcount pinned.
    Shared pages (prefix reuse) carry refcount > 1; writes to them must
    go through copy-on-write (``PagedCachePool._ensure_exclusive``).
    Invariants (property-tested in tests/test_cachepool.py):

    * conservation: ``n_free + len(live) + 1 == n_pages`` always;
    * no double allocation: ``alloc`` never returns a live page;
    * refcounts never go negative (``decref`` on a free page raises);
    * full churn drains clean: freeing everything restores ``n_free``
      to ``n_pages - 1``.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (zero page + 1 usable)")
        self.n_pages = n_pages
        # pop() from the tail -> pages hand out in ascending order
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self.refcount = np.zeros((n_pages,), np.int64)
        self.refcount[0] = 1  # the zero page is permanently pinned
        self.high_water = 0   # max live pages ever (capacity reporting)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def live(self) -> List[int]:
        return [p for p in range(1, self.n_pages) if self.refcount[p] > 0]

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError(f"page pool exhausted ({self.n_pages} pages)")
        pid = self._free.pop()
        assert self.refcount[pid] == 0, f"double allocation of page {pid}"
        self.refcount[pid] = 1
        self.high_water = max(self.high_water, self.n_pages - 1 - len(self._free))
        return pid

    def incref(self, pid: int) -> None:
        if pid == 0:
            return  # the zero page is shared by construction
        if self.refcount[pid] <= 0:
            raise ValueError(f"incref on free page {pid}")
        self.refcount[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        if pid == 0:
            return False
        if self.refcount[pid] <= 0:
            raise ValueError(f"decref on free page {pid} (refcount underflow)")
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self._free.append(pid)
            return True
        return False


# ---------------------------------------------------------------------------
# prefix hashing + cache
# ---------------------------------------------------------------------------


def token_hash_chain(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """The prefix-sharing hash contract: digest ``i`` commits to the
    ENTIRE token prefix ``tokens[0:(i+1)*page_size]`` — each full
    page's tokens are hashed together with the previous page's digest
    (SHA-256, collision-safe: a match is treated as content identity).
    Only FULL pages enter the chain; a partial tail page is never
    shared."""
    chain: List[bytes] = []
    h = b""
    for i in range(len(tokens) // page_size):
        page = np.asarray(
            tokens[i * page_size : (i + 1) * page_size], np.int64
        ).tobytes()
        h = hashlib.sha256(h + page).digest()
        chain.append(h)
    return chain


class PrefixCache:
    """Chain-digest -> page-run map with LRU eviction.

    Entry ``i`` (keyed by the chain's ``i``-th digest) holds the page
    ids of blocks ``[0, i+1)``; the cache holds its OWN reference on
    every page of every entry, so a page stays resident while any entry
    (or any slot) still points at it.  ``evict_lru`` releases one
    entry's references — pages whose refcount drops to zero return to
    the allocator's free list."""

    def __init__(self, allocator: PageAllocator):
        self._alloc = allocator
        self._entries: "OrderedDict[bytes, Tuple[int, ...]]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def match(self, chain: Sequence[bytes]) -> Tuple[int, Tuple[int, ...]]:
        """Longest cached prefix: returns ``(n_pages, page_ids)`` with
        ``n_pages`` full pages matched (0 = miss)."""
        for i in range(len(chain), 0, -1):
            pages = self._entries.get(chain[i - 1])
            if pages is not None:
                self._entries.move_to_end(chain[i - 1])
                return i, pages
        return 0, ()

    def insert(self, key: bytes, pages: Sequence[int]) -> bool:
        """Record a page run under its chain digest (takes a reference
        on every page).  Returns False if the key was already present
        (just refreshed its LRU position)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        for p in pages:
            self._alloc.incref(p)
        self._entries[key] = tuple(pages)
        return True

    def evict_lru(self) -> int:
        """Release the least-recently-used entry; returns the number of
        pages actually FREED (refcount reached zero)."""
        if not self._entries:
            return 0
        _, pages = self._entries.popitem(last=False)
        return sum(1 for p in pages if self._alloc.decref(p))

    def drop_all(self) -> int:
        freed = 0
        while self._entries:
            freed += self.evict_lru()
        return freed


# ---------------------------------------------------------------------------
# the CacheOps protocol + contiguous implementation
# ---------------------------------------------------------------------------


@runtime_checkable
class CacheOps(Protocol):
    """The single cache-lifecycle surface both pool layouts implement.

    All methods are FUNCTIONAL over the device state tree returned by
    :meth:`alloc` (jit/donation friendly); host-side bookkeeping (block
    tables, refcounts) lives inside the implementation.
    """

    kind: str

    def alloc(self):
        """Allocate the device cache state for ``n_slots`` lanes."""
        ...

    def write(self, state, single, slot: int):
        """Scatter a single-request cache tree (leaves
        ``(n_periods, 1, ...)``) into ``slot``."""
        ...

    def read(self, state, slot: int):
        """Extract ``slot``'s logical cache as a single-request tree."""
        ...

    def reset(self, state, slot: int):
        """Evict ``slot``: restore its logical cache to the pristine
        fill (payload 0, position sentinel -1, SSM state 0)."""
        ...

    def snapshot(self, state, slot: int):
        """Copy of ``slot``'s logical cache (restore token)."""
        ...

    def restore(self, state, snap, slot: int):
        """Put a :meth:`snapshot` back into ``slot``."""
        ...


class ContiguousCacheOps:
    """The legacy slot-contiguous pool behind :class:`CacheOps`.

    Pure delegation to the historical helpers (``init_caches`` /
    ``write_cache_slot`` / ``reset_cache_slot``) — bit-identity with
    direct helper calls is pinned by tests/test_cachepool.py, which is
    what licenses the serving engine to route its admission/eviction
    writes through this object instead of the helpers."""

    kind = "contiguous"

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype

    def alloc(self):
        return init_caches(self.cfg, self.n_slots, self.max_len, dtype=self.dtype)

    def write(self, state, single, slot):
        return write_cache_slot(state, single, slot)

    def read(self, state, slot):
        return jax.tree.map(lambda l: l[:, slot : slot + 1], state)

    def reset(self, state, slot):
        return reset_cache_slot(state, self.cfg, slot)

    def snapshot(self, state, slot):
        return jax.tree.map(lambda l: l[:, slot : slot + 1].copy(), state)

    def restore(self, state, snap, slot):
        return write_cache_slot(state, snap, slot)


# ---------------------------------------------------------------------------
# the paged pool
# ---------------------------------------------------------------------------


class PagedCachePool:
    """Fixed-size pages + free-list block tables (see module docstring).

    Device state tree (returned by :meth:`alloc`):

    * ``state["pages"][key][leaf]`` — ``(n_periods, n_pages, page_size,
      ...)`` for every position-indexed cache ``key``;
    * ``state["slot"][key][leaf]`` — the cumulative SSM leaves,
      slot-contiguous exactly as in the contiguous pool.

    Jit-safe adapters (device tables passed as arguments so table
    edits never retrace): :meth:`device_view`, :meth:`commit_rows`,
    :meth:`slot_view`, :meth:`slot_commit`.  Host lifecycle:
    :meth:`prepare_admission`, :meth:`ensure_rows`, :meth:`free_slot`,
    :meth:`finish_admission`.
    """

    kind = "paged"

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 page_size: int, dtype=jnp.float32, *,
                 n_pages: Optional[int] = None, prefix_sharing: bool = False,
                 registry=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.dtype = dtype
        layout = cache_layout(cfg, max_len)
        self.slot_keys = [k for k, _, L in layout if L is None]

        # group position-indexed caches by length L: one allocator + one
        # block table per group (same L -> same block arithmetic, so all
        # the group's leaves can share page ids)
        by_len: Dict[int, List[str]] = {}
        for key, _, L in layout:
            if L is not None:
                by_len.setdefault(L, []).append(key)
        for L in by_len:
            if L % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide every cache length; "
                    f"got L={L} (sliding window shorter than a page? use a "
                    f"page_size that divides the smallest window)"
                )

        self.shareable = bool(by_len) and not self.slot_keys and set(by_len) == {max_len}
        if prefix_sharing and not self.shareable:
            raise ValueError(
                "prefix_sharing requires a model whose caches are all "
                "full-context position-indexed (no sliding windows, no SSM "
                f"state); {cfg.name} has layout {[(k, L) for k, _, L in layout]}"
            )
        self.prefix_sharing = prefix_sharing

        self.groups: Dict[str, dict] = {}
        for L, keys in sorted(by_len.items()):
            nb = L // page_size
            if L == max_len and n_pages is not None:
                npg = n_pages
            else:
                npg = n_slots * nb + 1  # exact contiguous footprint + zero page
                if L == max_len and prefix_sharing:
                    npg += n_slots * nb  # headroom for resident prefix entries
            self.groups[f"L{L}"] = {
                "L": L,
                "nb": nb,
                "keys": list(keys),
                "alloc": PageAllocator(npg),
                "table": np.zeros((n_slots, nb), np.int32),
            }
        self._tables_dev = None  # device mirror, rebuilt when dirty
        self._dirty = True
        self.prefix: Optional[PrefixCache] = None
        if prefix_sharing:
            self.prefix = PrefixCache(self.groups[f"L{max_len}"]["alloc"])

        # pool telemetry: shares the server's registry when given, keeps
        # a private one otherwise (counting is always on — see
        # repro.runtime.telemetry's overhead contract).  Gauges are
        # refreshed on demand by :meth:`scrape_gauges`, not per alloc.
        if registry is None:
            from repro.runtime.telemetry import MetricsRegistry
            registry = MetricsRegistry()
        self._registry = registry
        self._m_allocs = registry.counter(
            "page_allocs_total", "pages handed out by the free-list allocator",
            labelnames=("group",))
        self._m_cow = registry.counter(
            "cow_copies_total", "copy-on-write page duplications")
        self._m_prefix_evictions = registry.counter(
            "prefix_evictions_total",
            "prefix-cache LRU entries released under page pressure")
        self._m_pages_free = registry.gauge(
            "pages_free", "free pages per group", labelnames=("group",))
        self._m_pages_live = registry.gauge(
            "pages_live", "resident (refcounted) pages per group",
            labelnames=("group",))
        self._m_pages_hw = registry.gauge(
            "pages_high_water", "max pages ever live per group",
            labelnames=("group",))
        self._m_prefix_entries = registry.gauge(
            "prefix_entries", "prefix-cache entries resident")

        # leaf templates from the contiguous initializer: the paged pool
        # stores EXACTLY the same leaves, page-major
        single = init_caches(cfg, 1, max_len, dtype=dtype)
        self._templates = {
            key: {name: (leaf.shape, leaf.dtype) for name, leaf in single[key].items()}
            for key in single
        }
        self._build_jits()

    # -- device state -------------------------------------------------------

    def _fill(self, name):
        return -1 if name == "pos" else 0

    def alloc(self):
        pages = {}
        for g in self.groups.values():
            npg = g["alloc"].n_pages
            for key in g["keys"]:
                pages[key] = {}
                for name, (shape, dt) in self._templates[key].items():
                    tail = shape[3:]  # (P, 1, L, *tail)
                    P = shape[0]
                    pages[key][name] = jnp.full(
                        (P, npg, self.page_size) + tail, self._fill(name), dt
                    )
        slot = {}
        for key in self.slot_keys:
            slot[key] = {
                name: jnp.zeros((shape[0], self.n_slots) + shape[2:], dt)
                for name, (shape, dt) in self._templates[key].items()
            }
        return {"pages": pages, "slot": slot}

    def device_tables(self):
        """Device mirror of the block tables (a jit ARGUMENT — content
        changes never retrace)."""
        if self._dirty or self._tables_dev is None:
            self._tables_dev = {
                gk: jnp.asarray(g["table"]) for gk, g in self.groups.items()
            }
            self._dirty = False
        return self._tables_dev

    def slot_tables(self, slot: int):
        """One slot's table rows (device), for the B=1 admission path."""
        return {gk: jnp.asarray(g["table"][slot]) for gk, g in self.groups.items()}

    def scatter_ids(self, slot: int):
        """Per-group scatter targets for a whole-slot commit: the
        slot's page id per block, with non-writable blocks (the zero
        page, and any SHARED page) remapped out of range so a
        ``mode="drop"`` scatter skips them.  Shared pages are read-only
        by contract — a writer must copy-on-write first."""
        out = {}
        for gk, g in self.groups.items():
            row = g["table"][slot].copy()
            rc = g["alloc"].refcount
            drop = (row == 0) | (rc[row] > 1)
            row[drop] = g["alloc"].n_pages  # out of range -> dropped
            out[gk] = jnp.asarray(row)
        return out

    # -- jit-safe gather/scatter adapters -----------------------------------

    def _build_jits(self):
        ps = self.page_size

        def zero_pages(state, gk, pids):
            """Restore pages ``pids`` (padded with out-of-range ids) of
            one group to the pristine fill — freshly allocated pages
            must not expose a previous occupant's rows."""
            pages = dict(state["pages"])
            for key in self.groups[gk]["keys"]:
                leaves = {}
                for name, arr in pages[key].items():
                    fill = jnp.full(
                        (arr.shape[0], pids.shape[0]) + arr.shape[2:],
                        self._fill(name), arr.dtype,
                    )
                    leaves[name] = arr.at[:, pids].set(fill, mode="drop")
                pages[key] = leaves
            return {"pages": pages, "slot": state["slot"]}

        def copy_page(state, gk, src, dst):
            """Copy-on-write body: duplicate one page of one group."""
            pages = dict(state["pages"])
            for key in self.groups[gk]["keys"]:
                pages[key] = {
                    name: arr.at[:, dst].set(arr[:, src])
                    for name, arr in pages[key].items()
                }
            return {"pages": pages, "slot": state["slot"]}

        self._zero_pages = {
            gk: jax.jit(lambda state, pids, gk=gk: zero_pages(state, gk, pids),
                        donate_argnums=(0,))
            for gk in self.groups
        }
        self._copy_page = {
            gk: jax.jit(lambda state, src, dst, gk=gk: copy_page(state, gk, src, dst),
                        donate_argnums=(0,))
            for gk in self.groups
        }

    def device_view(self, state, tables):
        """Gather the logical ``(n_periods, B, L, ...)`` cache tree the
        model steps consume — bit-identical values to the contiguous
        pool holding the same logical content (unallocated blocks show
        the zero page's pristine rows)."""
        view = {}
        for gk, g in self.groups.items():
            t = tables[gk]  # (B, nb)
            for key in g["keys"]:
                view[key] = {}
                for name, arr in state["pages"][key].items():
                    gathered = arr[:, t]  # (P, B, nb, ps, *tail)
                    P, B = gathered.shape[0], gathered.shape[1]
                    view[key][name] = gathered.reshape(
                        (P, B, g["nb"] * self.page_size) + gathered.shape[4:]
                    )
        for key in self.slot_keys:
            view[key] = state["slot"][key]
        return view

    def commit_rows(self, state, tables, view, pos, mask, n_rows: int = 1):
        """Scatter ``n_rows`` decode-step rows per lane from a logical
        view back into the pages (masked lanes write nothing), and fold
        the cumulative SSM leaves under the same mask.  Row ``j`` of
        lane ``b`` lives at logical position ``pos[b] + j`` (mod L for
        rolling windows); for speculative verify the view's rejected
        rows already carry the rolled-back ``before`` bits, so the
        scatter IS the page-granular restore."""
        ps = self.page_size
        pages = {k: dict(v) for k, v in state["pages"].items()}
        for gk, g in self.groups.items():
            L, NP = g["L"], g["alloc"].n_pages
            t = tables[gk]  # (B, nb)
            for j in range(n_rows):
                idx = (pos + j) % L                     # (B,) logical row
                block = idx // ps
                pid = jnp.take_along_axis(t, block[:, None], axis=1)[:, 0]
                pid = jnp.where(mask, pid, NP)          # masked -> dropped
                off = idx % ps
                for key in g["keys"]:
                    for name, arr in pages[key].items():
                        v = view[key][name]             # (P, B, L, *tail)
                        ir = idx.reshape((1, -1, 1) + (1,) * (v.ndim - 3))
                        row = jnp.take_along_axis(v, ir, axis=2)[:, :, 0]
                        pages[key][name] = arr.at[:, pid, off].set(
                            row.astype(arr.dtype), mode="drop"
                        )
        slot = {}
        for key in self.slot_keys:
            slot[key] = {}
            for name, arr in state["slot"][key].items():
                m = mask.reshape((1, -1) + (1,) * (arr.ndim - 2))
                slot[key][name] = jnp.where(
                    m, view[key][name].astype(arr.dtype), arr
                )
        return {"pages": pages, "slot": slot}

    def slot_view(self, state, slot_tables, slot):
        """One slot's logical cache as a ``(n_periods, 1, ...)`` tree
        (the chunked-prefill admission view)."""
        view = {}
        for gk, g in self.groups.items():
            t = slot_tables[gk]  # (nb,)
            for key in g["keys"]:
                view[key] = {}
                for name, arr in state["pages"][key].items():
                    gathered = arr[:, t]  # (P, nb, ps, *tail)
                    view[key][name] = gathered.reshape(
                        (gathered.shape[0], 1, g["nb"] * self.page_size)
                        + gathered.shape[3:]
                    )
        for key in self.slot_keys:
            view[key] = {
                name: jax.lax.dynamic_slice_in_dim(arr, slot, 1, axis=1)
                for name, arr in state["slot"][key].items()
            }
        return view

    def slot_commit(self, state, scatter_ids, slot, view):
        """Scatter a whole single-slot view back: every WRITABLE block
        (allocated and exclusive — see :meth:`scatter_ids`) receives
        its page worth of rows; shared/zero blocks are dropped (their
        view rows are bit-identical to the page content by
        construction: prefix pages are read-only and padded segment
        writes were rolled back before commit)."""
        pages = {k: dict(v) for k, v in state["pages"].items()}
        for gk, g in self.groups.items():
            sp = scatter_ids[gk]  # (nb,) page ids, non-writable -> out of range
            for key in g["keys"]:
                for name, arr in pages[key].items():
                    v = view[key][name]  # (P, 1, L, *tail)
                    blocks = v.reshape(
                        (v.shape[0], g["nb"], self.page_size) + v.shape[3:]
                    )
                    pages[key][name] = arr.at[:, sp].set(
                        blocks.astype(arr.dtype), mode="drop"
                    )
        slot_leaves = {}
        for key in self.slot_keys:
            slot_leaves[key] = {
                name: jax.lax.dynamic_update_slice_in_dim(
                    arr, view[key][name].astype(arr.dtype), slot, axis=1
                )
                for name, arr in state["slot"][key].items()
            }
        return {"pages": pages, "slot": slot_leaves}

    # -- host lifecycle ------------------------------------------------------

    def _evict_prefix(self) -> int:
        """Release one LRU prefix entry (counted); returns pages freed."""
        had = len(self.prefix)
        freed = self.prefix.evict_lru()
        if len(self.prefix) < had:
            self._m_prefix_evictions.inc()
        return freed

    def _alloc_page(self, gk: str) -> int:
        """Allocate one page, evicting LRU prefix entries under
        pressure; raises MemoryError when the pool is truly full."""
        g = self.groups[gk]
        while True:
            try:
                pid = g["alloc"].alloc()
                self._m_allocs.inc(group=gk)
                return pid
            except MemoryError:
                if self.prefix is None or not self._evict_prefix():
                    raise MemoryError(
                        f"page pool {gk} exhausted "
                        f"({g['alloc'].n_pages} pages, none evictable); "
                        "raise ServingConfig.n_pages"
                    ) from None

    def _attach_fresh(self, state, slot: int, gk: str, blocks: Sequence[int]):
        """Allocate + pristine-zero pages for ``blocks`` of ``slot``."""
        g = self.groups[gk]
        fresh = []
        for b in blocks:
            pid = self._alloc_page(gk)
            g["table"][slot, b] = pid
            fresh.append(pid)
        if fresh:
            pids = np.full((g["nb"],), g["alloc"].n_pages, np.int32)
            pids[: len(fresh)] = fresh
            state = self._zero_pages[gk](state, jnp.asarray(pids))
            self._dirty = True
        return state

    def _ensure_exclusive(self, state, slot: int, gk: str, block: int):
        """Copy-on-write: make ``block`` of ``slot`` privately owned
        before a write can land on it."""
        g = self.groups[gk]
        pid = int(g["table"][slot, block])
        if pid != 0 and g["alloc"].refcount[pid] == 1:
            return state
        dst = self._alloc_page(gk)
        if pid == 0:
            # fresh block: pristine-fill instead of copying the zero page
            pids = np.full((g["nb"],), g["alloc"].n_pages, np.int32)
            pids[0] = dst
            state = self._zero_pages[gk](state, jnp.asarray(pids))
        else:
            state = self._copy_page[gk](state, jnp.int32(pid), jnp.int32(dst))
            g["alloc"].decref(pid)
            self._m_cow.inc()
        g["table"][slot, block] = dst
        self._dirty = True
        return state

    def ensure_rows(self, state, slot: int, lo: int, hi: int):
        """Make positions ``[lo, hi]`` of ``slot`` writable in every
        group: allocate missing blocks (pristine), copy-on-write shared
        ones.  The per-decode-step host check (cheap: almost always a
        no-op integer compare)."""
        ps = self.page_size
        for gk, g in self.groups.items():
            L = g["L"]
            blocks = sorted({((p % L) // ps) for p in range(lo, hi + 1)})
            missing = [b for b in blocks if g["table"][slot, b] == 0]
            if missing:
                state = self._attach_fresh(state, slot, gk, missing)
            for b in blocks:
                pid = int(g["table"][slot, b])
                if g["alloc"].refcount[pid] > 1:
                    state = self._ensure_exclusive(state, slot, gk, b)
        return state

    def prepare_admission(self, state, slot: int, prompt: Sequence[int]):
        """Admission setup for one request: prefix match + attach, then
        allocate the rest of the prompt's blocks (plus the first decode
        block) fresh.  Sliding-window groups allocate their whole
        (small) window — chunked prefill wraps through it.  Returns
        ``(state, matched_tokens, chain)``."""
        plen = len(prompt)
        for g in self.groups.values():
            assert (g["table"][slot] == 0).all(), (
                f"slot {slot} still holds pages — free_slot before re-admission"
            )
        matched = 0
        chain: List[bytes] = []
        if self.prefix is not None:
            chain = token_hash_chain(prompt, self.page_size)
            # a full-page-aligned prompt must keep its LAST page partial
            # from the matcher's perspective: position plen (the first
            # decode write) lands in block plen // ps, which must be
            # writable, so never attach it shared
            n_match, pages = self.prefix.match(chain[: max(0, (plen - 1) // self.page_size)])
            if n_match:
                gk = f"L{self.max_len}"
                g = self.groups[gk]
                for b in range(n_match):
                    g["alloc"].incref(pages[b])
                    g["table"][slot, b] = pages[b]
                self._dirty = True
                matched = n_match * self.page_size
        ps = self.page_size
        for gk, g in self.groups.items():
            if g["L"] < self.max_len:
                blocks = list(range(g["nb"]))  # the whole rolling window
            else:
                blocks = list(range(matched // ps, plen // ps + 1))
            missing = [b for b in blocks if g["table"][slot, b] == 0]
            state = self._attach_fresh(state, slot, gk, missing)
        return state, matched, chain

    def finish_admission(self, slot: int, chain: Sequence[bytes], matched: int) -> int:
        """After the tail prefill: publish this slot's full-page runs
        into the prefix cache (boundaries the match didn't already
        cover).  Returns the number of NEW entries inserted."""
        if self.prefix is None or not chain:
            return 0
        g = self.groups[f"L{self.max_len}"]
        inserted = 0
        for i in range(matched // self.page_size + 1, len(chain) + 1):
            if self.prefix.insert(chain[i - 1], g["table"][slot, :i].tolist()):
                inserted += 1
        return inserted

    def free_slot(self, slot: int) -> None:
        """Eviction: release every table reference of the slot (freed
        pages keep their stale bits — allocation pristine-fills)."""
        for g in self.groups.values():
            row = g["table"][slot]
            for b in range(g["nb"]):
                if row[b]:
                    g["alloc"].decref(int(row[b]))
            row[:] = 0
        self._dirty = True

    def can_admit(self, prompt: Sequence[int]) -> bool:
        """Capacity predicate for scheduler admission: enough free (or
        LRU-evictable) pages for the prompt's worst-case block span in
        every group (prefix-match savings are NOT assumed)."""
        plen = len(prompt)
        for g in self.groups.values():
            if g["L"] < self.max_len:
                need = g["nb"]
            else:
                need = plen // self.page_size + 1
            free = g["alloc"].n_free
            if free < need and self.prefix is not None:
                while free < need and self._evict_prefix() >= 0 and len(self.prefix):
                    free = g["alloc"].n_free
                free = g["alloc"].n_free
            if free < need:
                return False
        return True

    # -- CacheOps completeness (host/test paths, eager jnp) ------------------

    def write(self, state, single, slot):
        """Scatter a fully-populated single-request tree into ``slot``
        (allocates the slot's whole block span — protocol parity with
        the contiguous pool's admission write)."""
        for gk, g in self.groups.items():
            missing = [b for b in range(g["nb"]) if g["table"][slot, b] == 0]
            state = self._attach_fresh(state, slot, gk, missing)
        for gk in self.groups:
            for b in range(self.groups[gk]["nb"]):
                state = self._ensure_exclusive(state, slot, gk, b)
        return self.slot_commit(
            state, self.scatter_ids(slot), jnp.int32(slot), single
        )

    def read(self, state, slot):
        return self.slot_view(state, self.slot_tables(slot), jnp.int32(slot))

    def reset(self, state, slot):
        self.free_slot(slot)
        slot_leaves = {}
        for key in self.slot_keys:
            slot_leaves[key] = {
                name: arr.at[:, slot].set(0)
                for name, arr in state["slot"][key].items()
            }
        return {"pages": state["pages"], "slot": slot_leaves}

    def snapshot(self, state, slot):
        return jax.tree.map(lambda l: l.copy(), self.read(state, slot))

    def restore(self, state, snap, slot):
        return self.write(state, snap, slot)

    # -- reporting -----------------------------------------------------------

    def scrape_gauges(self) -> None:
        """Refresh the occupancy gauges (``pages_free`` / ``pages_live``
        / ``pages_high_water`` per group, ``prefix_entries``) from the
        allocators.  Called at snapshot/export time rather than per
        alloc — gauges are point-in-time reads, not event counts."""
        for gk, g in self.groups.items():
            a = g["alloc"]
            self._m_pages_free.set(a.n_free, group=gk)
            self._m_pages_live.set(a.n_pages - 1 - a.n_free, group=gk)
            self._m_pages_hw.set(a.high_water, group=gk)
        if self.prefix is not None:
            self._m_prefix_entries.set(len(self.prefix))

    def report(self) -> dict:
        """Capacity numbers for the serving benchmark: pages resident /
        high-water per group, plus the contiguous-equivalent row count
        the same workload would have reserved."""
        out = {"page_size": self.page_size, "groups": {}}
        for gk, g in self.groups.items():
            a = g["alloc"]
            out["groups"][gk] = {
                "n_pages": a.n_pages,
                "live": len(a.live()),
                "high_water": a.high_water,
                "contiguous_pages_equiv": self.n_slots * g["nb"],
            }
        if self.prefix is not None:
            out["prefix_entries"] = len(self.prefix)
        return out
