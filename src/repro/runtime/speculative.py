"""Ladder-speculative decoding: draft at a cheap rung, verify at f32.

The precision ladder IS the draft/verify pair — no separate draft
model, same weights.  A :class:`LadderSpeculativeDecoder` round:

1. **draft** — ``k`` greedy tokens through the fused FAST path at a
   configurable draft rung (``q8_8`` snaps activations to the paper's
   Q8.8 grid before the W8A8 int8 dot; ``q16_16`` is the standard FAST
   path), each step a single-token :func:`~repro.models.decode_step`.
   The draft pass works on a throwaway copy of the caches — its
   mutations are never committed.
2. **verify** — ALL ``k+1`` positions (current token + k drafts) in ONE
   batched :func:`~repro.models.segment_step` at the ``f32``/"exact"
   rung.  ``argmax`` of the verify logits is, by construction, exactly
   what vanilla f32 greedy decode would have emitted at each position
   *given the same prefix* — so the longest prefix of drafts agreeing
   with the verify argmaxes, PLUS the verify argmax at the first
   disagreement (or at the end), can all be accepted.  Per round the
   decoder therefore commits between 1 and ``k+1`` tokens, every one of
   them an f32-exact token.
3. **rollback** — :func:`~repro.models.commit_segment` merges the
   verified segment into the caches, restoring every REJECTED
   position's cache entries bit-for-bit (position-indexed KV entries
   revert to their pre-segment contents; the cumulative SSM state rolls
   back to the per-position candidate recorded during the segment).

Exactness contract (pinned by tests/spec_harness.py across model
families x draft rungs x seeds): the emitted token stream is
token-for-token identical to vanilla f32 greedy decode, REGARDLESS of
what the draft rung produces — a garbage draft costs throughput (every
round still commits >= 1 verified token), never correctness.  This is
the transprecision thesis in its sharpest form: the fast path is pure
speculation; the precise path remains the sole correctness anchor.

Acceptance-rate accounting: per round and per lane, ``k`` drafted /
``m`` accepted (``m = `` length of the agreeing prefix).  The measured
rate is a live precision signal — the serving integration feeds it to
:class:`~repro.core.arbiter.SlotArbiter`, whose sustained-low-acceptance
escalation moves a slot's DRAFT rung up the ladder (cheap drafts that
keep missing cost more verify rounds than they save).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import MathEngine
from repro.models import (
    commit_segment,
    decode_step,
    init_caches,
    prefill_step,
    segment_step,
    write_cache_slot,
)
from repro.models.config import ModelConfig
from repro.models.layers import attach_quantized_weights

__all__ = [
    "SPEC_DRAFT_LEVELS",
    "SpeculativeConfig",
    "LadderSpeculativeDecoder",
    "register_spec_steps",
]

#: draft rungs: engine level name -> model-layer dispatch string.  The
#: f32 verify rung is NOT a draft option — drafting at the verify
#: precision is strictly more work than vanilla decode.
SPEC_DRAFT_LEVELS = (("q8_8", "fast8"), ("q16_16", "fast"))

#: serving caches are f32 (the exact-mode consistency contract — see
#: repro.runtime.serve.SERVE_CACHE_DTYPE).
SPEC_CACHE_DTYPE = jnp.float32


@dataclasses.dataclass
class SpeculativeConfig:
    """Knobs for one speculative decoder (or the server's spec mode).

    ``k``: drafts per round (compile-time constant: the draft scan and
    the k+1-wide verify segment are shaped by it).  ``draft_level``:
    starting rung, one of :data:`SPEC_DRAFT_LEVELS`.  ``collect_trace``:
    keep a per-round host trace (drafts, verify argmaxes, commit
    counts) — the exactness harness replays it through a NumPy
    reference simulator to check the acceptance accounting.
    """

    k: int = 4
    draft_level: str = "q8_8"
    max_len: int = 256
    eos_id: Optional[int] = None
    collect_trace: bool = False

    def __post_init__(self):
        names = tuple(lv for lv, _ in SPEC_DRAFT_LEVELS)
        if self.k < 1:
            raise ValueError("speculative k must be >= 1")
        if self.draft_level not in names:
            raise ValueError(
                f"draft_level {self.draft_level!r} not a draft rung {names}"
            )


def _min_window(cfg: ModelConfig) -> Optional[int]:
    ws = [l.window for l in cfg.period if l.window is not None]
    return min(ws) if ws else None


def register_spec_steps(engine: MathEngine, cfg: ModelConfig, k: int):
    """Register the draft/verify step functions on ``engine`` and return
    ``(draft_dispatch, verify_fn, draft_level_names)``.

    ``draft_dispatch(level_idx, params, tok, pos, caches, lane_mask)``
    runs ``k`` greedy single-token decode steps at the (traced) draft
    rung and returns the drafted tokens (B, k); its cache mutations
    live only inside the jit and are discarded.

    ``verify_fn(params, tok, pos, drafts, caches, mask)`` runs the
    batched f32 segment pass, computes the longest agreeing prefix, and
    commits/rolls back the caches in the same dispatch.  Returns
    ``(preds (B,k+1), n_commit (B,), caches', new_tok (B,),
    new_pos (B,), finite (B,), amp (B,))``.
    """
    w = _min_window(cfg)
    if w is not None and k + 1 > w:
        raise ValueError(
            f"speculative k={k} needs k+1 <= smallest attention window ({w}): "
            "a verify segment must fit the rolling KV buffer"
        )

    def make_draft(mode):
        def fn(params, tok, pos, caches, lane_mask):
            def body(carry, _):
                t, p, c = carry
                logits, c = decode_step(
                    params, t[:, None], p, c, cfg, mode=mode, lane_mask=lane_mask
                )
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (nxt, p + 1, c), nxt

            (_, _, _), drafts = jax.lax.scan(body, (tok, pos, caches), None, length=k)
            return drafts.T  # (k, B) -> (B, k)

        return fn

    engine.register("spec_draft", **{lv: make_draft(m) for lv, m in SPEC_DRAFT_LEVELS})
    draft_names = tuple(lv for lv, _ in SPEC_DRAFT_LEVELS)
    draft_disp, _ = engine.switched("spec_draft", levels=draft_names)
    draft_disp = jax.jit(draft_disp)

    def verify(params, tok, pos, drafts, caches, mask):
        B = tok.shape[0]
        seg = jnp.concatenate([tok[:, None], drafts], axis=1)          # (B, k+1)
        seg_pos = pos[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None]
        logits, after, aux = segment_step(
            params, seg, seg_pos, caches, cfg, mode="exact", lane_mask=mask
        )
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)          # (B, k+1)
        match = (drafts == preds[:, :k]).astype(jnp.int32)
        m = jnp.cumprod(match, axis=1).sum(axis=1)                     # (B,) in [0, k]
        n_commit = jnp.where(mask, m + 1, 0)
        keep_pos = pos + m                                             # last accepted position
        caches = commit_segment(
            caches, after, aux, cfg,
            keep_pos=keep_pos, keep_count=n_commit, active=mask,
        )
        last = jnp.take_along_axis(
            preds, jnp.clip(n_commit - 1, 0, k)[:, None], axis=1
        )[:, 0]
        new_tok = jnp.where(mask, last, tok)
        new_pos = pos + n_commit
        finite = jnp.all(jnp.isfinite(logits), axis=(1, 2)) | ~mask
        amp = jnp.where(mask, jnp.max(jnp.abs(logits), axis=(1, 2)), 0.0)
        return preds, n_commit, caches, new_tok, new_pos, finite, amp

    return draft_disp, jax.jit(verify), draft_names


class LadderSpeculativeDecoder:
    """Standalone speculative greedy decoder (the exactness-harness
    subject and the benchmark unit; the serving integration lives in
    :class:`~repro.runtime.serve.ContinuousBatchingServer`).

    ``generate`` prefills each prompt at f32/"exact" (the same anchor
    vanilla serving uses), then loops draft -> verify -> commit rounds
    until every lane has its ``max_new`` tokens (or EOS).  The emitted
    stream per lane is exactly ``max_new`` f32-greedy tokens.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: SpeculativeConfig,
                 engine: Optional[MathEngine] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.engine = engine or MathEngine(scfg.draft_level)
        self.params = attach_quantized_weights(
            params, self.engine.weight_cache, level="q16_16"
        )
        self._draft, self._verify, self.draft_levels = register_spec_steps(
            self.engine, cfg, scfg.k
        )
        self._prefill = jax.jit(
            lambda params, tokens, caches: prefill_step(
                params, tokens, caches, cfg, mode="exact"
            )
        )
        self._write = jax.jit(write_cache_slot)
        self.stats: Dict[str, int] = {"rounds": 0, "drafted": 0, "accepted": 0}
        self.trace: List[dict] = []

    @property
    def acceptance_rate(self) -> float:
        d = self.stats["drafted"]
        return self.stats["accepted"] / d if d else float("nan")

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 draft_level: Optional[str] = None) -> List[List[int]]:
        """Greedy speculative decode; returns per-prompt GENERATED
        tokens (the first from the f32 prefill, like the servers).
        Prompts may be ragged — each is prefilled at its exact length.
        """
        scfg = self.scfg
        k = scfg.k
        B = len(prompts)
        level = draft_level or scfg.draft_level
        li = jnp.int32(self.draft_levels.index(level))
        need = max(len(p) for p in prompts) + max_new + k
        if need > scfg.max_len:
            raise ValueError(
                f"max_len {scfg.max_len} too small: longest prompt + max_new + k "
                f"needs {need} positions of speculative headroom"
            )

        caches = init_caches(self.cfg, B, scfg.max_len, dtype=SPEC_CACHE_DTYPE)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            single = init_caches(self.cfg, 1, scfg.max_len, dtype=SPEC_CACHE_DTYPE)
            logits, single = self._prefill(
                self.params, jnp.asarray([list(p)], jnp.int32), single
            )
            caches = self._write(caches, single, jnp.int32(i))
            tok[i] = int(jnp.argmax(logits, axis=-1)[0])
            pos[i] = len(p)

        out: List[List[int]] = [[int(tok[i])] for i in range(B)]
        done = np.zeros((B,), bool)
        if scfg.eos_id is not None:
            done |= tok == scfg.eos_id
        done |= max_new <= 1
        tok_d = jnp.asarray(tok)
        pos_d = jnp.asarray(pos)

        while not done.all():
            mask = jnp.asarray(~done)
            drafts = self._draft(li, self.params, tok_d, pos_d, caches, mask)
            preds, n_commit, caches, tok_d, pos_d, _, _ = self._verify(
                self.params, tok_d, pos_d, drafts, caches, mask
            )
            preds_h = np.asarray(preds)
            n_h = np.asarray(n_commit)
            self.stats["rounds"] += 1
            self.stats["drafted"] += int(k * (~done).sum())
            self.stats["accepted"] += int(np.maximum(n_h - 1, 0).sum())
            if scfg.collect_trace:
                self.trace.append({
                    "drafts": np.asarray(drafts).copy(),
                    "preds": preds_h.copy(),
                    "n_commit": n_h.copy(),
                    "active": (~done).copy(),
                })
            for i in range(B):
                if done[i]:
                    continue
                for j in range(int(n_h[i])):
                    t = int(preds_h[i, j])
                    out[i].append(t)
                    if scfg.eos_id is not None and t == scfg.eos_id:
                        done[i] = True
                        break
                    if len(out[i]) >= max_new:
                        done[i] = True
                        break
        return [o[:max_new] for o in out]


def vanilla_greedy_reference(cfg: ModelConfig, params, prompts, max_new: int,
                             max_len: int, eos_id: Optional[int] = None,
                             engine: Optional[MathEngine] = None) -> List[List[int]]:
    """The correctness oracle: plain f32/"exact" greedy decode, one
    token at a time — what the speculative stream must match
    token-for-token."""
    engine = engine or MathEngine("f32")
    params = attach_quantized_weights(params, engine.weight_cache, level="q16_16")
    pre = jax.jit(lambda pr, t, c: prefill_step(pr, t, c, cfg, mode="exact"))
    dec = jax.jit(lambda pr, t, p, c: decode_step(pr, t, p, c, cfg, mode="exact"))
    outs = []
    for p in prompts:
        caches = init_caches(cfg, 1, max_len, dtype=SPEC_CACHE_DTYPE)
        logits, caches = pre(params, jnp.asarray([list(p)], jnp.int32), caches)
        cur = int(jnp.argmax(logits, axis=-1)[0])
        toks = [cur]
        pos = len(p)
        while len(toks) < max_new and not (eos_id is not None and cur == eos_id):
            logits, caches = dec(
                params, jnp.asarray([[cur]], jnp.int32), jnp.asarray([pos], jnp.int32),
                caches,
            )
            cur = int(jnp.argmax(logits, axis=-1)[0])
            toks.append(cur)
            pos += 1
        outs.append(toks)
    return outs
