"""The unified serving configuration surface.

Historically the two servers grew their own kwarg sprawls
(``ServerConfig`` for the static :class:`~repro.runtime.serve.\
BatchedServer``, ``ContinuousServerConfig`` for the continuous engine)
plus a third implicit surface of per-call knobs.  :class:`ServingConfig`
consolidates them: ONE validated dataclass that both servers accept and
that also carries the cache-layout policy introduced with the paged
pool (``cache`` / ``page_size`` / ``prefill_chunk`` / ``prefix_sharing``
/ ``n_pages``).  The old dataclasses survive as deprecation-warned
shims in :mod:`repro.runtime.serve`.

Validation happens eagerly in ``__post_init__`` — a config that
constructs is a config a server can build from (model-dependent checks
such as "page_size divides every sliding window" run at server build,
where the :class:`~repro.models.config.ModelConfig` is known).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.core.arbiter import SlotArbiterConfig
from repro.runtime.speculative import SpeculativeConfig
from repro.runtime.telemetry import TelemetryConfig

__all__ = ["ServingConfig", "SERVE_STEP_LEVELS", "SERVE_CACHE_DTYPE"]

#: engine levels the serve steps are implemented at -> model-layer
#: dispatch string.  The precise rung runs the models' "exact" (f32
#: serving) mode rather than the bf16 training mode — see the
#: repro.runtime.serve module docstring.
SERVE_STEP_LEVELS = (("q16_16", "fast"), ("f32", "exact"))

#: serving caches are f32 (bf16 would round the decode side of the
#: prefill/decode consistency contract only); quantized KV stays the
#: FAST-path memory option.
SERVE_CACHE_DTYPE = jnp.float32


@dataclasses.dataclass
class ServingConfig:
    """One config for both servers.

    Core (both servers): ``n_slots`` (device lanes / max static batch),
    ``max_len`` (context window = pool length), ``eos_id``,
    ``temperature``, ``default_level`` (per-request requests may
    override on the continuous engine; the static server's single
    level), ``seed``.

    Continuous-engine knobs: ``health_sync_every``, ``arbiter``,
    ``speculative`` — see :class:`~repro.runtime.serve.\
    ContinuousBatchingServer`.

    Static-server knob: ``max_new`` (per-wave decode budget; the
    continuous engine takes budgets per request).

    Cache layout (continuous engine):

    * ``cache="contiguous"`` — the legacy slot-contiguous pool: every
      slot owns ``max_len`` cache rows for its lifetime.
    * ``cache="paged"`` — fixed-size pages + free-list block tables
      (see :mod:`repro.runtime.cachepool`): slots map logical blocks to
      physical pages, admission runs CHUNKED prefill
      (``prefill_chunk``-token fixed-shape segments — zero retraces
      across prompt lengths), and ``prefix_sharing=True`` shares
      full pages between requests with a common token prefix
      (copy-on-write, token-hash keyed).

    ``page_size`` must divide ``max_len`` (and, checked at server
    build, every sliding-window cache length).  ``prefill_chunk``
    defaults to ``page_size`` on the paged path; prefix sharing
    REQUIRES chunk == page_size so page contents are a deterministic
    function of the token prefix alone (chunk boundaries land on the
    same global grid regardless of how much prefix was reused).
    ``n_pages`` overrides the full-length page-pool size (default:
    2x the contiguous footprint when sharing is on, 1x + headroom
    otherwise).
    """

    n_slots: int = 4
    max_len: int = 256
    eos_id: Optional[int] = None
    temperature: float = 0.0          # 0 = greedy
    default_level: Any = "f32"        # ladder level name (or Mode alias
                                      # for the static server)
    seed: int = 0
    #: health-signal sync cadence (decode steps) when NO eos_id is set.
    health_sync_every: int = 8
    arbiter: SlotArbiterConfig = dataclasses.field(
        default_factory=lambda: SlotArbiterConfig(n_levels=len(SERVE_STEP_LEVELS))
    )
    #: enable ladder-speculative decoding for requests that ask for it.
    speculative: Optional[SpeculativeConfig] = None
    #: static-server per-wave decode budget.
    max_new: int = 32
    #: cache layout: "contiguous" (legacy slot rows) | "paged".
    cache: str = "contiguous"
    #: physical page length (cache rows per page) on the paged path.
    page_size: int = 16
    #: chunked-prefill segment length; None = page_size on the paged
    #: path (the contiguous path keeps whole-prompt prefill).
    prefill_chunk: Optional[int] = None
    #: share full prefix pages between requests (paged path only;
    #: requires a model whose caches are all full-context
    #: position-indexed — no sliding windows, no SSM state).
    prefix_sharing: bool = False
    #: total pages in the full-length page pool (incl. the reserved
    #: zero page); None = a validated default.
    n_pages: Optional[int] = None
    #: runtime telemetry (see repro.runtime.telemetry).  The metrics
    #: REGISTRY is always on (plain host counters, same cost as the
    #: counting hooks it replaced); ``telemetry.enabled`` additionally
    #: turns on the span tracer / tick profiler, and
    #: ``telemetry.sync_device`` opts into device barriers for honest
    #: phase timings (changes performance, never tokens).
    telemetry: TelemetryConfig = dataclasses.field(default_factory=TelemetryConfig)

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.max_len < 2:
            raise ValueError("max_len must be >= 2")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.health_sync_every < 1:
            raise ValueError("health_sync_every must be >= 1")
        if self.cache not in ("contiguous", "paged"):
            raise ValueError(f"cache must be 'contiguous' or 'paged', got {self.cache!r}")
        if self.cache == "paged":
            if self.page_size < 1:
                raise ValueError("page_size must be >= 1")
            if self.max_len % self.page_size:
                raise ValueError(
                    f"page_size {self.page_size} must divide max_len {self.max_len}"
                )
        if self.prefill_chunk is not None:
            if self.cache != "paged":
                raise ValueError("prefill_chunk requires cache='paged'")
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            if self.max_len % self.prefill_chunk:
                raise ValueError(
                    f"prefill_chunk {self.prefill_chunk} must divide max_len {self.max_len}"
                )
        if self.prefix_sharing:
            if self.cache != "paged":
                raise ValueError("prefix_sharing requires cache='paged'")
            if self.resolved_chunk != self.page_size:
                raise ValueError(
                    "prefix_sharing requires prefill_chunk == page_size: page "
                    "contents must be a deterministic function of the token "
                    "prefix alone (chunk boundaries must land on the page grid "
                    "regardless of how much prefix was matched)"
                )
        if self.n_pages is not None:
            if self.cache != "paged":
                raise ValueError("n_pages requires cache='paged'")
            # every slot needs its max_len worth of blocks available in
            # the worst case, plus the reserved zero page
            if self.n_pages < self.max_len // self.page_size + 1:
                raise ValueError(
                    f"n_pages {self.n_pages} cannot hold even one slot's "
                    f"{self.max_len // self.page_size} blocks (+1 zero page)"
                )

    @property
    def resolved_chunk(self) -> Optional[int]:
        """The effective chunked-prefill segment length (None =
        whole-prompt prefill, the contiguous path's legacy behavior)."""
        if self.cache != "paged":
            return self.prefill_chunk
        return self.prefill_chunk if self.prefill_chunk is not None else self.page_size
