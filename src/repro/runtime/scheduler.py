"""Continuous-batching admission/eviction scheduler.

The serving engine keeps a FIXED device batch of ``n_slots`` lanes (the
slot-paged KV/SSM pool is allocated once at server build).  Requests
flow through three states:

    pending (FIFO queue)  --admit-->  active (bound to a slot)
                                      --finish-->  done (slot freed)

``ContinuousScheduler`` is the pure host-side core of that loop: it
owns the queue, the slot table and per-request token bookkeeping, and
decides *which* request occupies *which* slot *when* — but touches no
device state.  The server (:class:`repro.runtime.serve.\
ContinuousBatchingServer`) drives it and performs the corresponding
device work (per-slot prefill scatter, pool decode, cache reset).

Termination of a request is any of: EOS sampled (when ``eos_id`` is
configured), its own ``max_new`` budget exhausted, or the shared
``max_len`` context window reached.  Because budgets are per-request,
short requests free their slots early and the next pending request is
admitted — the continuous-batching win over the static
``BatchedServer``, which decodes every lane until the LONGEST request
in the wave finishes.

Invariants (asserted, and pinned by tests/test_scheduler.py):

* a slot is bound to at most one active request at a time;
* admission is FIFO over submission order;
* every submitted request is eventually finished exactly once;
* a finished request's output = prompt + generated tokens (EOS kept,
  like the static server).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Request", "FinishedRequest", "ContinuousScheduler"]


@dataclasses.dataclass
class Request:
    """One serving request.

    ``level``: ladder level name this request runs at (``None`` =
    server default).  The request's precision may be *escalated* above
    this at runtime by the per-slot arbiter, never demoted below it.

    ``speculative``: serve this request through ladder-speculative
    decoding (draft at a cheap rung, verify at f32 — see
    :mod:`repro.runtime.speculative`).  Output is identical to vanilla
    f32 greedy decode; only throughput changes.  Requires the server to
    be built with a ``speculative`` config.
    """

    rid: int
    prompt: List[int]
    max_new: int = 32
    level: Optional[str] = None
    speculative: bool = False

    def __post_init__(self):
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: List[int]            # prompt + generated (EOS kept)
    n_generated: int
    reason: str                  # 'eos' | 'max_new' | 'max_len'


@dataclasses.dataclass
class _SlotEntry:
    request: Request
    n_generated: int = 0

    @property
    def pos(self) -> int:
        """Next decode position = tokens written to the cache so far."""
        return len(self.request.prompt) + self.n_generated


class ContinuousScheduler:
    def __init__(self, n_slots: int, max_len: int, eos_id: Optional[int] = None,
                 levels: Optional[Tuple[str, ...]] = None, registry=None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.levels = tuple(levels) if levels is not None else None
        self.pending: Deque[Request] = deque()
        self.slots: List[Optional[_SlotEntry]] = [None] * n_slots
        self.finished: Dict[int, FinishedRequest] = {}
        self._submitted: set = set()
        # queue/admission metrics: on the server's registry when given,
        # a private one otherwise (counting is always on — see
        # repro.runtime.telemetry's overhead contract)
        if registry is None:
            from repro.runtime.telemetry import MetricsRegistry
            registry = MetricsRegistry()
        self._m_queue = registry.gauge(
            "queue_depth", "requests pending admission")
        self._m_blocked = registry.counter(
            "admission_blocked_total",
            "admit() calls that left the head request pending",
            labelnames=("reason",))

    # -- submission ---------------------------------------------------------

    def validate(self, req: Request) -> None:
        """All request validation lives here, BEFORE any queue/slot
        state changes: a request that fails after admit() would leave a
        zombie slot entry behind and corrupt the server for every later
        serve() call."""
        if req.rid in self._submitted:
            raise ValueError(f"duplicate request id {req.rid}")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} >= max_len {self.max_len}"
            )
        if (self.levels is not None and req.level is not None
                and req.level not in self.levels):
            raise ValueError(
                f"request {req.rid}: unknown level {req.level!r}; have {self.levels}"
            )

    def submit(self, req: Request) -> None:
        self.validate(req)
        self._submitted.add(req.rid)
        self.pending.append(req)
        self._m_queue.set(len(self.pending))

    def pop_finished(self, rid: int) -> FinishedRequest:
        """Hand a finished request's result out and RELEASE the rid:
        per-request bookkeeping is dropped (the scheduler outlives its
        requests and must not grow with lifetime traffic), and the rid
        becomes reusable for a future submission."""
        fin = self.finished.pop(rid)
        self._submitted.discard(rid)
        return fin

    # -- state views --------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.pending) or any(e is not None for e in self.slots)

    def active_mask(self) -> np.ndarray:
        return np.array([e is not None for e in self.slots], bool)

    def active_slots(self) -> List[int]:
        return [i for i, e in enumerate(self.slots) if e is not None]

    def request_at(self, slot: int) -> Request:
        e = self.slots[slot]
        assert e is not None, f"slot {slot} is empty"
        return e.request

    # -- admission ----------------------------------------------------------

    def admit(self, can_admit=None, limit=None) -> List[Tuple[int, Request]]:
        """Bind pending requests to free slots, FIFO.  Returns the
        (slot, request) pairs the server must now prefill + scatter.

        ``can_admit(request) -> bool`` is an optional CAPACITY predicate
        (the paged pool's free-page check): admission stops at the FIRST
        rejected request — skipping ahead would break FIFO order, and
        the head request becomes admissible again as running requests
        finish and release their pages.

        ``limit`` caps admissions per call.  A capacity-predicated
        caller MUST admit one request per call (``limit=1``) and
        allocate before calling again: the predicate reads free
        capacity at call time, so approving several requests in one
        batch would check them all against the same un-decremented
        free-page count and over-commit the pool.

        A call that leaves the head request pending records WHY in the
        ``admission_blocked_total{reason=...}`` counter: ``capacity``
        (the predicate rejected it) or ``slots_full`` (no free slot) —
        a ``limit`` cut is not blockage (the caller loops)."""
        out = []
        capacity_blocked = False
        limit_cut = False
        for i in range(self.n_slots):
            if not self.pending:
                break
            if limit is not None and len(out) >= limit:
                limit_cut = True
                break
            if self.slots[i] is None:
                if can_admit is not None and not can_admit(self.pending[0]):
                    capacity_blocked = True
                    break
                req = self.pending.popleft()
                self.slots[i] = _SlotEntry(req)
                out.append((i, req))
        if self.pending and not limit_cut:
            if capacity_blocked:
                self._m_blocked.inc(reason="capacity")
            elif all(e is not None for e in self.slots):
                self._m_blocked.inc(reason="slots_full")
        self._m_queue.set(len(self.pending))
        return out

    # -- per-token bookkeeping ---------------------------------------------
    #
    # Token VALUES live in the server's device ring buffer until a
    # request finishes (keeping the decode loop free of per-step host
    # syncs); the scheduler tracks only counts — plus the EOS flag the
    # server passes in when it runs with per-step EOS checks.

    def n_generated(self, slot: int) -> int:
        e = self.slots[slot]
        assert e is not None, f"slot {slot} is empty"
        return e.n_generated

    def position(self, slot: int) -> int:
        """Next decode position of the slot's request (prompt length +
        generated so far) — the server's speculative-headroom check."""
        e = self.slots[slot]
        assert e is not None, f"slot {slot} is empty"
        return e.pos

    def advance(self, slot: int, eos: bool = False) -> Optional[str]:
        """Count one generated token for the slot's request (the first
        comes from prefill, the rest from pool decode steps).  Returns
        the termination reason if this token finishes the request —
        the caller must then :meth:`finish` the slot with the pulled
        token values and reset its device state before reuse."""
        e = self.slots[slot]
        assert e is not None, f"advance on empty slot {slot}"
        e.n_generated += 1
        if eos and self.eos_id is not None:
            return "eos"
        if e.n_generated >= e.request.max_new:
            return "max_new"
        if e.pos >= self.max_len:
            return "max_len"
        return None

    def finish(self, slot: int, generated: List[int], reason: str) -> FinishedRequest:
        """Materialize the finished request (token values pulled from
        the device by the caller) and free the slot."""
        e = self.slots[slot]
        assert e is not None, f"finish on empty slot {slot}"
        assert len(generated) == e.n_generated, (len(generated), e.n_generated)
        fin = FinishedRequest(
            rid=e.request.rid,
            tokens=list(e.request.prompt) + [int(t) for t in generated],
            n_generated=e.n_generated,
            reason=reason,
        )
        assert fin.rid not in self.finished, f"request {fin.rid} finished twice"
        self.finished[fin.rid] = fin
        self.slots[slot] = None
        return fin
