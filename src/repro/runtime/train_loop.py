"""Production training loop: the paper's runtime precision engine wired
into a fault-tolerant trainer, on the precision-ladder API.

* train-step executables are registered per precision level (the
  quantized path at ``q16_16``, the float path at ``f32``) — switches
  mid-run are the paper's O(1) pointer swap behind the two-phase
  barrier, or — with ``jit_switch=True`` — a *traced* level index fed
  to one ``jax.lax.switch``-dispatched executable, so level changes
  take effect inside the compiled step with zero retraces;
* the PrecisionArbiter watches loss/grad-norm and recommends ladder
  transitions (cheap levels on healthy numerics, step-up on
  spikes/NaNs);
* checkpoints are atomic + async (checkpoint/checkpointer.py); restart
  resumes bitwise (deterministic data keyed by step);
* a straggler watchdog tracks a per-step wall-clock EMA and surfaces
  slow steps (on real multi-host deployments this feeds the
  replace-worker path; here it is telemetry + tests);
* failure injection (``crash_at_step``) exercises the restart path in
  integration tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import MathEngine, Mode, resolve_level
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params, train_loss
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state

__all__ = ["TrainerConfig", "Trainer"]

#: engine levels the train step is implemented at, and the model-layer
#: dispatch string each one lowers to (models/* pdot etc. speak the
#: binary fast/precise vocabulary at the matmul level).
TRAIN_STEP_LEVELS = (("q16_16", "fast"), ("f32", "precise"))


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    start_mode: Any = Mode.PRECISE    # Mode compat alias or ladder level name
    use_arbiter: bool = False
    arbiter: ArbiterConfig = dataclasses.field(default_factory=ArbiterConfig)
    jit_switch: bool = False          # dispatch by traced level index (no host swap)
    straggler_factor: float = 3.0     # step slower than factor x EMA -> flagged
    crash_at_step: Optional[int] = None  # failure injection (tests)
    seed: int = 0


class InjectedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        data_cfg: Optional[DataConfig] = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig(
            lr=1e-3,
            total_steps=tcfg.total_steps,
            warmup_steps=max(1, min(200, tcfg.total_steps // 10)),
        )
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=min(cfg.max_seq, 64), global_batch=4
        )
        self.data = SyntheticLM(self.data_cfg)
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.engine = MathEngine(tcfg.start_mode)
        self.arbiter = self._make_arbiter(tcfg) if tcfg.use_arbiter else None
        self.history: list = []
        self.straggler_events: list = []
        self._ema_step_s: Optional[float] = None

        self._build_steps()
        self._init_state()

    # -- setup ---------------------------------------------------------------

    @staticmethod
    def _make_arbiter(tcfg: TrainerConfig) -> PrecisionArbiter:
        """Build the arbiter with its start rung synced to the engine's
        start level — otherwise its first recommendation would silently
        move the engine to wherever the arbiter *believed* it was.  A
        start level outside the arbiter's ladder is a config error, not
        a silent demotion."""
        acfg = tcfg.arbiter
        start = resolve_level(tcfg.start_mode).name
        by_level = {resolve_level(e).name: e for e in acfg.ladder}
        if start not in by_level:
            raise ValueError(
                f"start_mode {tcfg.start_mode!r} (level {start}) is not in the "
                f"arbiter ladder {acfg.ladder!r}; pass an ArbiterConfig whose "
                f"ladder contains it"
            )
        if resolve_level(acfg.start_mode).name != start:
            acfg = dataclasses.replace(acfg, start_mode=by_level[start])
        return PrecisionArbiter(acfg)

    def _build_steps(self):
        cfg, opt_cfg = self.cfg, self.opt_cfg

        def make(mode: str, jit: bool = True) -> Callable:
            def step(params, opt_state, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: train_loss(p, batch, cfg, mode=mode), has_aux=True
                )(params)
                params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
                return params, opt_state, dict(metrics, loss=loss, **om)

            return jax.jit(step, donate_argnums=(0, 1)) if jit else step

        # the dispatch table 𝒟, one executable per ladder level; each
        # path is traced/compiled up-front on first call and set_level
        # never re-traces (verified in tests)
        level_names = tuple(lv for lv, _ in TRAIN_STEP_LEVELS)
        self.engine.register(
            "train_step",
            **{lv: make(mode, jit=not self.tcfg.jit_switch) for lv, mode in TRAIN_STEP_LEVELS},
        )
        if self.tcfg.jit_switch:
            # jit-safe functional dispatch: ONE executable whose first
            # argument is the (traced) level index — ladder moves inside
            # the compiled step, zero retraces (donation is off: lax.switch
            # branches share their operands).
            dispatch, self._switch_levels = self.engine.switched("train_step", level_names)
            self._switched_step = jax.jit(dispatch)
        else:
            self._switched_step = None
            self._switch_levels = level_names

    def _run_step(self, batch):
        if self._switched_step is not None:
            idx = jnp.int32(self.engine.level_index(self._switch_levels))
            return self._switched_step(idx, self.params, self.opt_state, batch)
        return self.engine.call("train_step", self.params, self.opt_state, batch)

    def _init_state(self):
        latest = self.ckpt.latest_step()
        if latest is not None:
            tmpl = {
                "params": init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed)),
                "opt": init_opt_state(init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))),
            }
            state = self.ckpt.restore(tmpl)
            self.params, self.opt_state = state["params"], state["opt"]
            self.start_step = latest + 1
            meta = state.get("meta", {})
        else:
            self.params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
            self.opt_state = init_opt_state(self.params)
            self.start_step = 0

    # -- loop ----------------------------------------------------------------

    def run(self) -> Dict:
        t = self.tcfg
        for step in range(self.start_step, t.total_steps):
            if t.crash_at_step is not None and step == t.crash_at_step:
                raise InjectedFailure(f"injected failure at step {step}")

            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch(step).items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._run_step(batch)
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            dt = time.perf_counter() - t0

            # straggler watchdog (EMA excludes the compile-heavy step 0)
            if self._ema_step_s is None:
                self._ema_step_s = dt
            else:
                if dt > t.straggler_factor * self._ema_step_s:
                    self.straggler_events.append({"step": step, "dt": dt, "ema": self._ema_step_s})
                self._ema_step_s = 0.9 * self._ema_step_s + 0.1 * dt

            self.history.append(
                {"step": step, "loss": loss, "grad_norm": gnorm,
                 "mode": self.engine.mode.value, "level": self.engine.level.name,
                 "dt": dt}
            )

            if self.arbiter is not None:
                rec = self.arbiter.observe(step, loss, gnorm)
                if rec is not None:
                    latency = self.engine.set_level(rec)
                    self.history[-1]["switched_to"] = getattr(rec, "value", rec)
                    self.history[-1]["switch_us"] = latency

            if t.ckpt_every and (step + 1) % t.ckpt_every == 0:
                self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})

        self.ckpt.wait()
        return {
            "history": self.history,
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "straggler_events": self.straggler_events,
            "switches": self.engine.switch_stats.count,
        }
