"""Runtime telemetry: metrics registry, per-request tracing, and the
decode-tick profiler — the observability layer the serving stack
reports through (see docs/observability.md for the metric catalogue
and span taxonomy).

Zero dependencies beyond the stdlib, and a two-tier overhead contract:

* the **registry tier** (counters / gauges / histograms) is ALWAYS on.
  Its hot-path cost is one dict update per event — the same plain host
  integer increments the serving stack already paid for its ad-hoc
  counting hooks (``stats`` dicts, ``_chunk_traces``,
  ``QuantizedWeightCache.quantize_calls``), which this module now
  hosts as first-class metrics;
* the **profiler tier** (the span tracer and the per-tick phase
  histograms) is gated on :class:`TelemetryConfig` ``enabled``.
  Disabled (the default) it contributes *nothing*: no ``perf_counter``
  calls, no span objects, and — the contract the async decode path
  depends on — **no host syncs**.  Even enabled, device timing stays
  async unless ``sync_device=True`` explicitly opts into the
  ``block_until_ready`` barriers that split device time from host time
  (the profiling mode, never the serving default).

Three export surfaces:

* ``registry.snapshot()`` — nested dict (embedded in every
  ``BENCH_*.json`` by ``benchmarks/run.py --json``);
* ``render_prometheus()`` — Prometheus text exposition format
  (``launch/serve.py --metrics-out``);
* ``Tracer.export()`` — Chrome ``trace_event`` JSON
  (``launch/serve.py --trace-out``), viewable in Perfetto
  (https://ui.perfetto.dev) or ``chrome://tracing``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "TelemetryConfig",
    "Telemetry",
    "render_prometheus",
    "DEFAULT_TIME_BUCKETS",
]

#: default histogram buckets for wall-clock phases (seconds): decode
#: ticks on smoke models land around 1-50 ms; real deployments at the
#: tail.  Cumulative ``le`` semantics at render time, +Inf implicit.
DEFAULT_TIME_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labelnames: Tuple[str, ...], labels: Dict[str, Any]) -> Tuple[str, ...]:
    """Canonical child key: label VALUES in declaration order.  Every
    declared label must be supplied, no extras — a typo'd label name
    would otherwise silently fork a new time series."""
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared labelnames {sorted(labelnames)}"
        )
    return tuple(str(labels[n]) for n in labelnames)


def _fmt_labels(labelnames: Tuple[str, ...], key: Tuple[str, ...],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = list(zip(labelnames, key)) + list(extra)
    if not pairs:
        return ""
    def esc(v: str) -> str:
        return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")
    return "{" + ",".join(f'{n}="{esc(v)}"' for n, v in pairs) + "}"


class _Metric:
    """Base: one named metric family holding per-label-tuple children."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)

    def _key(self, labels: Dict[str, Any]) -> Tuple[str, ...]:
        return _label_key(self.labelnames, labels)


class Counter(_Metric):
    """Monotonic counter.  ``inc(n, **labels)`` on the hot path;
    ``value(**labels)`` reads (0 for a never-incremented child)."""

    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        k = self._key(labels)
        self._values[k] = self._values.get(k, 0) + n

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0)

    def total(self) -> float:
        """Sum over every label combination."""
        return sum(self._values.values())

    def collect(self):
        return dict(self._values)


class Gauge(_Metric):
    """Point-in-time value; ``set`` / ``inc`` / ``dec``."""

    kind = "gauge"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, v: float, **labels) -> None:
        self._values[self._key(labels)] = float(v)

    def inc(self, n: float = 1, **labels) -> None:
        k = self._key(labels)
        self._values[k] = self._values.get(k, 0) + n

    def dec(self, n: float = 1, **labels) -> None:
        self.inc(-n, **labels)

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0)

    def collect(self):
        return dict(self._values)


class Histogram(_Metric):
    """Fixed-bucket histogram (Prometheus ``le`` semantics).  Bucket
    counts are stored per-bucket and cumulated at render time, so
    ``observe`` is one bisect + three dict updates."""

    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), buckets=DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, labelnames)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError(f"histogram {self.name}: need at least one bucket")
        self.buckets = b
        # per label key: [bucket_counts list, sum, count]
        self._series: Dict[Tuple[str, ...], list] = {}

    def observe(self, v: float, **labels) -> None:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = [[0] * (len(self.buckets) + 1), 0.0, 0]
        counts, _, _ = s
        lo, hi = 0, len(self.buckets)
        while lo < hi:  # first bucket with le >= v
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        counts[lo] += 1
        s[1] += v
        s[2] += 1

    def snapshot_series(self, key: Tuple[str, ...]) -> dict:
        counts, total, n = self._series[key]
        cum, acc = {}, 0
        for le, c in zip(self.buckets, counts[:-1]):
            acc += c
            cum[repr(le)] = acc
        cum["+Inf"] = acc + counts[-1]
        return {"count": n, "sum": total, "buckets": cum}

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return s[2] if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return s[1] if s else 0.0

    def collect(self):
        return {k: self.snapshot_series(k) for k in self._series}


class MetricsRegistry:
    """Name -> metric family, get-or-create.  Re-registering a name
    returns the existing family; a kind/label mismatch raises (two
    subsystems silently sharing a name under different schemas is a
    bug, not a merge)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}"
                    f"{m.labelnames}, requested {cls.kind}{tuple(labelnames)}"
                )
            return m
        m = cls(name, help, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames, buckets=buckets)

    def __iter__(self):
        return iter(self._metrics.values())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """Nested dict of every metric: unlabeled scalars flatten to
        ``{name: value}``; labeled families map a ``k=v,...`` label
        string to the value; histograms expose
        ``{count, sum, buckets}``."""
        out: Dict[str, Any] = {}
        for m in self:
            if isinstance(m, Histogram):
                series = {",".join(f"{n}={v}" for n, v in zip(m.labelnames, k))
                          or "": s for k, s in m.collect().items()}
                out[m.name] = series
                continue
            vals = m.collect()
            if not m.labelnames:
                out[m.name] = vals.get((), 0)
            else:
                out[m.name] = {
                    ",".join(f"{n}={v}" for n, v in zip(m.labelnames, k)): val
                    for k, val in vals.items()
                }
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for m in self:
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                for key in sorted(m.collect()):
                    s = m.snapshot_series(key)
                    for le, c in s["buckets"].items():
                        lab = _fmt_labels(m.labelnames, key, (("le", le),))
                        lines.append(f"{m.name}_bucket{lab} {c}")
                    lines.append(
                        f"{m.name}_sum{_fmt_labels(m.labelnames, key)} {s['sum']}")
                    lines.append(
                        f"{m.name}_count{_fmt_labels(m.labelnames, key)} {s['count']}")
                continue
            vals = m.collect()
            if not vals and not m.labelnames:
                vals = {(): 0}
            for key in sorted(vals):
                v = vals[key]
                v = int(v) if float(v).is_integer() else v
                lines.append(f"{m.name}{_fmt_labels(m.labelnames, key)} {v}")
        return "\n".join(lines) + "\n"


def render_prometheus(registry: MetricsRegistry) -> str:
    """Module-level alias for :meth:`MetricsRegistry.render_prometheus`."""
    return registry.render_prometheus()


# ---------------------------------------------------------------------------
# tracer: Chrome trace_event JSON
# ---------------------------------------------------------------------------


class _NullSpan:
    """The disabled-path span: a reusable no-op context manager."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span recorder exporting Chrome ``trace_event`` JSON.

    Event kinds used (see docs/observability.md for the taxonomy):

    * ``X`` complete spans — ``span()`` context manager (``ts``/``dur``
      in microseconds since tracer start);
    * ``b``/``e`` async-nestable pairs — request lifecycles that span
      many ticks and migrate between slots (``async_begin`` /
      ``async_end``, correlated by ``cat`` + ``id``);
    * ``i`` instants — point events (arbiter switches);
    * ``M`` metadata — thread names (``thread_name``).

    Bounded: past ``max_events`` new events are counted in ``dropped``
    instead of stored (a long-lived server must not grow host memory
    with lifetime traffic).
    """

    PID = 1

    def __init__(self, max_events: int = 200_000):
        if max_events < 1:
            raise ValueError("max_events must be >= 1")
        self.max_events = max_events
        self.events: List[dict] = []
        self.dropped = 0
        self._t0 = time.perf_counter_ns()
        self._names: Dict[int, str] = {}

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, tid: int = 0, cat: str = "serve",
             args: Optional[dict] = None):
        t0 = self.now_us()
        try:
            yield self
        finally:
            ev = {"name": name, "cat": cat, "ph": "X", "pid": self.PID,
                  "tid": tid, "ts": t0, "dur": self.now_us() - t0}
            if args:
                ev["args"] = args
            self._emit(ev)

    def instant(self, name: str, tid: int = 0, cat: str = "serve",
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "pid": self.PID, "tid": tid, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_begin(self, name: str, id: int, tid: int = 0,
                    cat: str = "request", args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "b", "id": id,
              "pid": self.PID, "tid": tid, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_end(self, name: str, id: int, tid: int = 0,
                  cat: str = "request", args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "e", "id": id,
              "pid": self.PID, "tid": tid, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def thread_name(self, tid: int, name: str) -> None:
        if self._names.get(tid) == name:
            return
        self._names[tid] = name
        self._emit({"name": "thread_name", "ph": "M", "pid": self.PID,
                    "tid": tid, "args": {"name": name}})

    def export(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


# ---------------------------------------------------------------------------
# config + facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TelemetryConfig:
    """Profiler-tier knobs (the registry tier is always on).

    ``enabled`` gates EVERYTHING below — disabled (the default) the
    serving loop takes no timestamps, records no spans, and adds no
    host syncs (pinned by tests/test_telemetry.py).

    ``trace`` collects the per-request span tree (Chrome trace_event).
    ``sync_device`` inserts ``block_until_ready`` barriers after the
    decode dispatch so the ``device_dispatch`` phase measures actual
    device time instead of async dispatch time — a profiling mode that
    DOES add per-tick syncs; never the serving default.
    """

    enabled: bool = False
    trace: bool = True
    trace_max_events: int = 200_000
    sync_device: bool = False
    tick_buckets: Tuple[float, ...] = DEFAULT_TIME_BUCKETS

    def __post_init__(self):
        if self.trace_max_events < 1:
            raise ValueError("trace_max_events must be >= 1")
        if self.sync_device and not self.enabled:
            raise ValueError("sync_device requires enabled=True")
        if not self.tick_buckets:
            raise ValueError("tick_buckets must be non-empty")


class Telemetry:
    """One registry + (optionally) one tracer, behind no-op guards.

    The serving stack holds exactly one of these per server; hot paths
    call ``span``/``instant``/``async_*`` unconditionally (no-ops when
    disabled) and guard *timestamp* work behind ``if telemetry.on:``.
    """

    def __init__(self, config: Optional[TelemetryConfig] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.config = config if config is not None else TelemetryConfig()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer: Optional[Tracer] = (
            Tracer(self.config.trace_max_events)
            if (self.config.enabled and self.config.trace) else None
        )

    @property
    def on(self) -> bool:
        """True when the profiler tier (timestamps + spans) is active."""
        return self.config.enabled

    # -- tracer passthroughs (no-ops when tracing is off) -------------------

    def span(self, name: str, tid: int = 0, cat: str = "serve",
             args: Optional[dict] = None):
        if self.tracer is None:
            return _NULL_SPAN
        return self.tracer.span(name, tid=tid, cat=cat, args=args)

    def instant(self, name: str, tid: int = 0, cat: str = "serve",
                args: Optional[dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, tid=tid, cat=cat, args=args)

    def async_begin(self, name: str, id: int, tid: int = 0,
                    args: Optional[dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.async_begin(name, id=id, tid=tid, args=args)

    def async_end(self, name: str, id: int, tid: int = 0,
                  args: Optional[dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.async_end(name, id=id, tid=tid, args=args)

    def thread_name(self, tid: int, name: str) -> None:
        if self.tracer is not None:
            self.tracer.thread_name(tid, name)

    # -- exports ------------------------------------------------------------

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()

    def trace_export(self) -> dict:
        if self.tracer is None:
            return {"traceEvents": [], "displayTimeUnit": "ms",
                    "otherData": {"dropped_events": 0}}
        return self.tracer.export()

    def write_trace(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.trace_export(), f)
