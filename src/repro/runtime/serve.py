"""Batched serving runtime: prefill + decode on the precision ladder.

Static batching: up to ``max_batch`` prompts are padded to a common
length, prefilled together, then decoded lock-step until ``max_new``
or EOS.  The decode step dispatches through the MathEngine, so a
server can move along the ladder (int8 matmuls + Q-format KV at
``q16_16`` <-> IEEE-754 at ``f32``) at request-boundary safety via the
two-phase barrier — the paper's envelope-based mode choice (§7.2)
applied to serving.  ``set_mode`` stays as the binary compat alias.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import MathEngine, Mode, PrecisionLevel
from repro.models import decode_step, init_caches, prefill_step
from repro.models.config import ModelConfig

__all__ = ["ServerConfig", "BatchedServer", "SERVE_STEP_LEVELS"]

#: engine levels the serve steps are implemented at -> model-layer
#: dispatch string (models/* speak the binary vocabulary at matmul level).
SERVE_STEP_LEVELS = (("q16_16", "fast"), ("f32", "precise"))


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 4
    max_len: int = 256
    max_new: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0          # 0 = greedy
    start_mode: Any = Mode.PRECISE    # Mode compat alias or ladder level name
    seed: int = 0


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.engine = MathEngine(scfg.start_mode)
        self._build()

    def _build(self):
        cfg, scfg = self.cfg, self.scfg

        def make_prefill(mode):
            def fn(params, tokens, caches):
                return prefill_step(params, tokens, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(2,))

        def make_decode(mode):
            def fn(params, tok, pos, caches):
                return decode_step(params, tok, pos, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(3,))

        self.engine.register(
            "prefill", **{lv: make_prefill(mode) for lv, mode in SERVE_STEP_LEVELS}
        )
        self.engine.register(
            "decode", **{lv: make_decode(mode) for lv, mode in SERVE_STEP_LEVELS}
        )

    def set_mode(self, mode: Any) -> float:
        return self.engine.set_level(mode)

    def set_level(self, level: Any) -> float:
        return self.engine.set_level(level)

    @property
    def level(self) -> PrecisionLevel:
        return self.engine.level

    def _sample(self, logits: np.ndarray, rng) -> np.ndarray:
        if self.scfg.temperature <= 0:
            return np.argmax(logits, axis=-1)
        p = jax.nn.softmax(jnp.asarray(logits) / self.scfg.temperature, axis=-1)
        return np.array(
            [rng.choice(p.shape[-1], p=np.asarray(p[i])) for i in range(p.shape[0])]
        )

    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Greedy/temperature generation for up to max_batch prompts."""
        scfg = self.scfg
        assert len(prompts) <= scfg.max_batch
        B = len(prompts)
        rng = np.random.default_rng(scfg.seed)

        # left-align, right-pad to the longest prompt
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
        lengths = np.array([len(p) for p in prompts], np.int32)

        caches = init_caches(self.cfg, B, scfg.max_len)
        logits, caches = self.engine.call("prefill", self.params, jnp.asarray(toks), caches)
        # note: prefill computes last-position logits; for per-row true
        # lengths we re-decode the tail tokens of shorter rows below.
        outs = [list(p) for p in prompts]
        cur = self._sample(np.asarray(logits, np.float32), rng)
        pos = np.full((B,), plen, np.int32)
        active = np.ones((B,), bool)

        for _ in range(scfg.max_new):
            for i in range(B):
                if active[i]:
                    outs[i].append(int(cur[i]))
                    if scfg.eos_id is not None and cur[i] == scfg.eos_id:
                        active[i] = False
            if not active.any() or pos.max() + 1 >= scfg.max_len:
                break
            logits, caches = self.engine.call(
                "decode", self.params, jnp.asarray(cur[:, None].astype(np.int32)),
                jnp.asarray(pos), caches,
            )
            cur = self._sample(np.asarray(logits, np.float32), rng)
            pos = pos + 1

        return outs
