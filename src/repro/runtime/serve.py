"""Batched serving runtime: prefill + decode on the precision ladder.

Static batching: up to ``max_batch`` prompts are padded to a common
length, prefilled together, then decoded lock-step until ``max_new``
or EOS.  The decode step dispatches through the MathEngine, so a
server can move along the ladder (int8 matmuls + Q-format KV at
``q16_16`` <-> IEEE-754 at ``f32``) at request-boundary safety via the
two-phase barrier — the paper's envelope-based mode choice (§7.2)
applied to serving.  ``set_mode`` stays as the binary compat alias.

FAST-path weights are quantized ONCE at server build through the
engine's :class:`~repro.core.quantization.QuantizedWeightCache`
(``attach_quantized_weights``): the decode step consumes pre-quantized
int8 payloads and never requantizes a weight, and the MLP hidden stage
runs the fused single-correction path (kernels/fused_mlp).  Sampling is
vectorized (``jax.random.categorical``) and the sampled token stays on
device across decode steps — the only per-token host sync left is the
(B,)-sized EOS check, and only when ``eos_id`` is configured.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import MathEngine, Mode, PrecisionLevel
from repro.models import decode_step, init_caches, prefill_step
from repro.models.config import ModelConfig
from repro.models.layers import attach_quantized_weights

__all__ = ["ServerConfig", "BatchedServer", "SERVE_STEP_LEVELS"]

#: engine levels the serve steps are implemented at -> model-layer
#: dispatch string (models/* speak the binary vocabulary at matmul level).
SERVE_STEP_LEVELS = (("q16_16", "fast"), ("f32", "precise"))


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 4
    max_len: int = 256
    max_new: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0          # 0 = greedy
    start_mode: Any = Mode.PRECISE    # Mode compat alias or ladder level name
    seed: int = 0


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.engine = MathEngine(scfg.start_mode)
        # quantize-once: every FAST weight gets its int8 payload here,
        # keyed in the engine's cache; the original float leaves stay
        # (precise path + re-attachment after invalidate_weights).
        self.params = attach_quantized_weights(
            params, self.engine.weight_cache, level="q16_16"
        )
        self._build()

    def _build(self):
        cfg = self.cfg

        def make_prefill(mode):
            def fn(params, tokens, caches):
                return prefill_step(params, tokens, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(2,))

        def make_decode(mode):
            def fn(params, tok, pos, caches):
                return decode_step(params, tok, pos, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(3,))

        self.engine.register(
            "prefill", **{lv: make_prefill(mode) for lv, mode in SERVE_STEP_LEVELS}
        )
        self.engine.register(
            "decode", **{lv: make_decode(mode) for lv, mode in SERVE_STEP_LEVELS}
        )

    def set_mode(self, mode: Any) -> float:
        return self.engine.set_level(mode)

    def set_level(self, level: Any) -> float:
        return self.engine.set_level(level)

    @property
    def level(self) -> PrecisionLevel:
        return self.engine.level

    def _sample(self, logits, key):
        """Vectorized sampling on device: greedy argmax or one batched
        ``jax.random.categorical`` — no per-row host loop, no full-vocab
        logit transfer.  Returns a device (B,) int32."""
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, jnp.asarray(logits, jnp.float32) / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Greedy/temperature generation for up to max_batch prompts."""
        scfg = self.scfg
        assert len(prompts) <= scfg.max_batch
        B = len(prompts)
        key = jax.random.PRNGKey(scfg.seed)

        # left-align, right-pad to the longest prompt
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        caches = init_caches(self.cfg, B, scfg.max_len)
        logits, caches = self.engine.call("prefill", self.params, jnp.asarray(toks), caches)
        # NB (pre-existing limitation): prefill returns logits at the
        # common padded last position, so in a mixed-length batch the
        # first sampled token of a shorter row conditions on its right
        # padding.  Same-length batches (all current callers) are exact.
        key, sub = jax.random.split(key)
        cur = self._sample(logits, sub)          # device (B,), stays there
        gen = [cur]
        pos = jnp.full((B,), plen, jnp.int32)    # device; rows move lock-step
        eos = scfg.eos_id
        done = np.zeros((B,), bool)

        for step in range(scfg.max_new - 1):
            if eos is not None:
                # the one remaining per-token sync: a (B,) token pull
                done |= np.asarray(gen[-1]) == eos
                if done.all():
                    break
            if plen + step + 1 >= scfg.max_len:
                break
            logits, caches = self.engine.call(
                "decode", self.params, gen[-1][:, None], pos, caches
            )
            key, sub = jax.random.split(key)
            gen.append(self._sample(logits, sub))
            pos = pos + 1

        # single bulk device->host transfer after the loop
        mat = np.stack([np.asarray(g) for g in gen], axis=1)  # (B, T)
        outs = []
        for i, p in enumerate(prompts):
            row = mat[i].tolist()
            if eos is not None and eos in row:
                row = row[: row.index(eos) + 1]
            outs.append(list(p) + row)
        return outs
