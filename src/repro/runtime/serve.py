"""Serving runtime: static batching + continuous batching on the
precision ladder.

Two servers share the per-level step registrations:

:class:`BatchedServer` (static batching, the original engine): up to
``max_batch`` prompts are padded to a common length, prefilled
together, then decoded lock-step until ``max_new`` or EOS.  Every lane
runs at the server's single current level; switching happens at
request-boundary safety via the two-phase barrier (``set_level``).

:class:`ContinuousBatchingServer` (the serving engine): a fixed device
batch of ``n_slots`` lanes over a slot-paged KV/SSM pool allocated
ONCE at build.  A :class:`~repro.runtime.scheduler.ContinuousScheduler`
interleaves per-request prefill (admission) with pool decode steps;
finished requests are evicted and their slots re-filled immediately, so
short requests never wait for long ones.  Each slot carries its own
ladder level — per-REQUEST precision — driven by a vectorized
:class:`~repro.core.arbiter.SlotArbiter` on the request's own
NaN/amplitude signals, and dispatched through the jit-safe
``engine.switched`` traced-index path: mixed-precision batches run with
ZERO retraces (one compiled pool step per active level per decode
step, merged by an on-device slot mask).

Migration (``BatchedServer`` -> scheduler engine):

=====================================  =====================================
static ``BatchedServer``               ``ContinuousBatchingServer``
=====================================  =====================================
``generate(prompts)`` lock-step wave   ``serve([Request(...)])`` streaming
one level for the whole batch          per-request ``Request.level`` +
                                       arbiter escalation per slot
padded common-length prefill           exact-length per-request prefill
(shorter rows see right padding)       (no padding artifacts)
decode until longest request           per-request ``max_new``; slot freed
                                       at EOS/budget and refilled
caches rebuilt per ``generate`` call   slot-paged pool allocated once
=====================================  =====================================

Precision levels: the ``f32`` rung maps to the model-layer ``"exact"``
mode (f32 residual stream/matmuls/head — see
:func:`repro.models.layers.pdot`), which is what makes greedy decode
agree with its own prefill re-derivation even for deep hybrid stacks
(jamba).  Serving caches are f32 for the same reason: prefill attends
to its freshly computed k/v, decode to the cache — a bf16 cache would
round one side only.  The FAST memory path (int8 Q-format KV) is
orthogonal and unaffected.

FAST-path weights are quantized ONCE at server build through the
engine's :class:`~repro.core.quantization.QuantizedWeightCache`
(``attach_quantized_weights``): decode consumes pre-quantized int8
payloads and never requantizes a weight, and the MLP hidden stage runs
the fused single-correction path (kernels/fused_mlp).  Sampling is
vectorized (``jax.random.categorical``) on device.  Host-sync budget:
with ``eos_id`` set, one (B, 3) pull per step — sampled token, finite
flag, logit amplitude — serves the EOS check AND the per-slot arbiter
signals in a single transfer; without ``eos_id`` the decode loop
dispatches fully async (tokens accumulate in a device ring, pulled
once per request at eviction; health syncs on a configurable cadence).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arbiter import SlotArbiter
from repro.core.precision import MathEngine, Mode, PrecisionLevel
from repro.models import (
    commit_segment,
    decode_step,
    init_caches,
    prefill_step,
    segment_step,
)
from repro.models.config import ModelConfig
from repro.models.layers import attach_quantized_weights
from repro.runtime.cachepool import CacheOps, ContiguousCacheOps, PagedCachePool
from repro.runtime.config import (
    SERVE_CACHE_DTYPE,
    SERVE_STEP_LEVELS,
    ServingConfig,
)
from repro.runtime.scheduler import ContinuousScheduler, FinishedRequest, Request
from repro.runtime.speculative import SPEC_DRAFT_LEVELS, register_spec_steps
from repro.runtime.telemetry import Telemetry

__all__ = [
    "ServingConfig",
    "ServerConfig",
    "BatchedServer",
    "ContinuousServerConfig",
    "ContinuousBatchingServer",
    "SERVE_STEP_LEVELS",
    "SERVE_CACHE_DTYPE",
]


@dataclasses.dataclass
class ServerConfig:
    """Deprecated: use :class:`~repro.runtime.config.ServingConfig`.

    The static server's historical kwarg surface (``max_batch`` /
    ``start_mode``).  Kept as a warning shim; :meth:`to_serving` is the
    field mapping."""

    max_batch: int = 4
    max_len: int = 256
    max_new: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0          # 0 = greedy
    start_mode: Any = Mode.PRECISE    # Mode compat alias or ladder level name
    seed: int = 0

    def __post_init__(self):
        warnings.warn(
            "ServerConfig is deprecated; use repro.runtime.ServingConfig "
            "(max_batch -> n_slots, start_mode -> default_level)",
            DeprecationWarning, stacklevel=3,
        )

    def to_serving(self) -> ServingConfig:
        return ServingConfig(
            n_slots=self.max_batch, max_len=self.max_len, eos_id=self.eos_id,
            temperature=self.temperature, default_level=self.start_mode,
            seed=self.seed, max_new=self.max_new,
        )


class BatchedServer:
    """Static batching (see module docstring for the migration table to
    :class:`ContinuousBatchingServer`, which supersedes this for mixed
    workloads — this class remains the lock-step baseline and the
    simplest correctness oracle)."""

    def __init__(self, cfg: ModelConfig, params, scfg):
        if isinstance(scfg, ServerConfig):
            scfg = scfg.to_serving()
        if scfg.cache != "contiguous":
            raise ValueError(
                "BatchedServer supports cache='contiguous' only; the paged "
                "pool lives on ContinuousBatchingServer"
            )
        self.cfg = cfg
        self.scfg = scfg
        self.telemetry = Telemetry(scfg.telemetry)
        self.engine = MathEngine(scfg.default_level)
        # the engine's weight-cache counting hooks report through this
        # server's registry (shows up in metrics_snapshot())
        self.engine.weight_cache.use_registry(self.telemetry.registry)
        # quantize-once: every FAST weight gets its int8 payload here,
        # keyed in the engine's cache; the original float leaves stay
        # (precise path + re-attachment after invalidate_weights).
        self.params = attach_quantized_weights(
            params, self.engine.weight_cache, level="q16_16"
        )
        self._build()

    def metrics_snapshot(self) -> dict:
        """Nested-dict snapshot of every registered metric."""
        return self.telemetry.registry.snapshot()

    def render_prometheus(self) -> str:
        return self.telemetry.render_prometheus()

    def _build(self):
        cfg = self.cfg

        def make_prefill(mode):
            def fn(params, tokens, caches):
                return prefill_step(params, tokens, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(2,))

        def make_decode(mode):
            def fn(params, tok, pos, caches):
                return decode_step(params, tok, pos, caches, cfg, mode=mode)
            return jax.jit(fn, donate_argnums=(3,))

        self.engine.register(
            "prefill", **{lv: make_prefill(mode) for lv, mode in SERVE_STEP_LEVELS}
        )
        self.engine.register(
            "decode", **{lv: make_decode(mode) for lv, mode in SERVE_STEP_LEVELS}
        )

    def set_mode(self, mode: Any) -> float:
        return self.engine.set_level(mode)

    def set_level(self, level: Any) -> float:
        return self.engine.set_level(level)

    @property
    def level(self) -> PrecisionLevel:
        return self.engine.level

    def _sample(self, logits, key):
        """Vectorized sampling on device: greedy argmax or one batched
        ``jax.random.categorical`` — no per-row host loop, no full-vocab
        logit transfer.  Returns a device (B,) int32."""
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, jnp.asarray(logits, jnp.float32) / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Greedy/temperature generation for up to n_slots prompts."""
        scfg = self.scfg
        assert len(prompts) <= scfg.n_slots
        B = len(prompts)
        key = jax.random.PRNGKey(scfg.seed)

        # left-align, right-pad to the longest prompt
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        caches = init_caches(self.cfg, B, scfg.max_len, dtype=SERVE_CACHE_DTYPE)
        logits, caches = self.engine.call("prefill", self.params, jnp.asarray(toks), caches)
        # NB (static-batching limitation): prefill returns logits at the
        # common padded last position, so in a mixed-length batch the
        # first sampled token of a shorter row conditions on its right
        # padding.  Same-length batches are exact; mixed-length traffic
        # belongs on ContinuousBatchingServer (exact-length prefill).
        key, sub = jax.random.split(key)
        cur = self._sample(logits, sub)          # device (B,), stays there
        gen = [cur]
        pos = jnp.full((B,), plen, jnp.int32)    # device; rows move lock-step
        eos = scfg.eos_id
        done = np.zeros((B,), bool)

        for step in range(scfg.max_new - 1):
            if eos is not None:
                # the one remaining per-token sync: a (B,) token pull
                done |= np.asarray(gen[-1]) == eos
                if done.all():
                    break
            if plen + step + 1 >= scfg.max_len:
                break
            logits, caches = self.engine.call(
                "decode", self.params, gen[-1][:, None], pos, caches
            )
            key, sub = jax.random.split(key)
            gen.append(self._sample(logits, sub))
            pos = pos + 1

        # single bulk device->host transfer after the loop
        mat = np.stack([np.asarray(g) for g in gen], axis=1)  # (B, T)
        outs = []
        for i, p in enumerate(prompts):
            row = mat[i].tolist()
            if eos is not None and eos in row:
                row = row[: row.index(eos) + 1]
            outs.append(list(p) + row)
        return outs


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


class ContinuousServerConfig(ServingConfig):
    """Deprecated: use :class:`~repro.runtime.config.ServingConfig`.

    Pure alias — every historical field (``n_slots`` ... ``speculative``)
    is a :class:`ServingConfig` field with the same name, default and
    position, so existing call sites work unchanged modulo the
    deprecation warning."""

    def __post_init__(self):
        warnings.warn(
            "ContinuousServerConfig is deprecated; use "
            "repro.runtime.ServingConfig (same field names)",
            DeprecationWarning, stacklevel=3,
        )
        super().__post_init__()


class ContinuousBatchingServer:
    """Continuous-batching engine with per-request precision.

    Device state (allocated once at build):

    * ``pool``  — stacked cache pytree for ``n_slots`` lanes x
      ``max_len`` (the slot-paged KV/SSM pool);
    * ``_tok`` / ``_pos`` — (n_slots,) current token / next position.

    Host state: the :class:`ContinuousScheduler` (queue + slot table +
    token bookkeeping) and the :class:`SlotArbiter` (per-slot ladder
    indices).

    One decode step runs the jitted pool step once per DISTINCT active
    level: the level is a traced ``lax.switch`` index (zero retraces),
    and each pass merges its slots' logits and cache rows under an
    on-device occupancy mask, so a batch mixing ``q16_16`` and ``f32``
    requests costs one compiled executable, not one compile per mix.

    Isolation contract (pinned by tests/test_scheduler.py): every
    lane's computation is row-independent (attention, SSD, batch-local
    MoE routing all operate per batch row), and each pass zeroes
    non-member lanes at the input (``lane_mask``) so the FAST path's
    per-TENSOR activation exponents cannot couple a request to other
    levels' lanes or to evicted residue — a request's output is
    therefore identical to serving it alone at its level.  (Multiple
    FAST requests decoding in the SAME pass still share one activation
    exponent; per-row activation scales are the noted next step.)
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServingConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.level_names = tuple(lv for lv, _ in SERVE_STEP_LEVELS)
        if scfg.default_level not in self.level_names:
            raise ValueError(
                f"default_level {scfg.default_level!r} not in {self.level_names}"
            )
        if scfg.arbiter.n_levels != len(self.level_names):
            raise ValueError("arbiter ladder size must match SERVE_STEP_LEVELS")
        # telemetry: ONE registry shared by every subsystem (scheduler,
        # page pool, weight cache, arbiter hooks) so metrics_snapshot()
        # is the whole server in one dict.  The registry tier is always
        # on; spans/timestamps only when scfg.telemetry.enabled.
        self.telemetry = Telemetry(scfg.telemetry)
        self._declare_metrics(self.telemetry.registry)
        self.engine = MathEngine(scfg.default_level)
        self.engine.weight_cache.use_registry(self.telemetry.registry)
        self.params = attach_quantized_weights(
            params, self.engine.weight_cache, level="q16_16"
        )
        if scfg.health_sync_every < 1:
            raise ValueError("health_sync_every must be >= 1")
        # the cache pool behind the CacheOps surface: slot-contiguous
        # rows (legacy) or the paged block pool — allocated once either
        # way, reused across every request the server ever serves
        self.paged = scfg.cache == "paged"
        self.cache_ops: CacheOps
        if self.paged:
            self.cache_ops = PagedCachePool(
                cfg, scfg.n_slots, scfg.max_len, scfg.page_size,
                dtype=SERVE_CACHE_DTYPE, n_pages=scfg.n_pages,
                prefix_sharing=scfg.prefix_sharing,
                registry=self.telemetry.registry,
            )
        else:
            self.cache_ops = ContiguousCacheOps(
                cfg, scfg.n_slots, scfg.max_len, dtype=SERVE_CACHE_DTYPE
            )
        self.pool = self.cache_ops.alloc()
        self._tok = jnp.zeros((scfg.n_slots,), jnp.int32)
        self._pos = jnp.zeros((scfg.n_slots,), jnp.int32)
        # generated tokens stay ON DEVICE in a per-slot ring (pulled
        # once per request at eviction); health signals accumulate
        # on device between syncs ([finite_and, amp_max] per slot).
        self._gen_buf = jnp.zeros((scfg.n_slots, scfg.max_len), jnp.int32)
        self._gen_count = jnp.zeros((scfg.n_slots,), jnp.int32)
        self._health = jnp.tile(jnp.asarray([1.0, 0.0], jnp.float32), (scfg.n_slots, 1))
        self.scheduler = ContinuousScheduler(
            scfg.n_slots, scfg.max_len, scfg.eos_id, levels=self.level_names,
            registry=self.telemetry.registry,
        )
        self.arbiter = SlotArbiter(scfg.n_slots, scfg.arbiter)
        self.arbiter.on_switch = self._make_switch_hook("serve", self.level_names)
        # speculative mode: a SEPARATE per-slot arbiter whose rungs index
        # the DRAFT ladder (SPEC_DRAFT_LEVELS) — acceptance-rate driven,
        # while self.arbiter keeps governing vanilla slots' serve levels.
        self.draft_arbiter: Optional[SlotArbiter] = None
        if scfg.speculative is not None:
            draft_names = tuple(lv for lv, _ in SPEC_DRAFT_LEVELS)
            self.draft_arbiter = SlotArbiter(
                scfg.n_slots,
                dataclasses.replace(
                    scfg.arbiter,
                    n_levels=len(draft_names),
                    start_idx=draft_names.index(scfg.speculative.draft_level),
                ),
            )
            self.draft_arbiter.on_switch = self._make_switch_hook(
                "draft", draft_names
            )
        self._key = jax.random.PRNGKey(scfg.seed)
        self._step = 0
        self._rid_counter = 0
        self._req_t0: Dict[int, float] = {}  # slot -> admission wall time
        if self.telemetry.on:
            self.telemetry.thread_name(0, "engine")
            for s in range(scfg.n_slots):
                self.telemetry.thread_name(s + 1, f"slot{s}")
        self._build()

    # -- telemetry ----------------------------------------------------------

    def _declare_metrics(self, reg) -> None:
        """Every serving metric family, registered up front (a metric
        that never fires still appears in the snapshot at 0 — absence
        means a typo, not an idle path).  See docs/observability.md."""
        tb = self.scfg.telemetry.tick_buckets
        self._m_decode_ticks = reg.counter(
            "decode_ticks_total", "pool decode steps executed")
        self._m_level_passes = reg.counter(
            "level_passes_total", "compiled pool passes per ladder level",
            labelnames=("level",))
        self._m_prefills = reg.counter(
            "prefills_total", "request prefills (admissions)")
        self._m_prefill_chunks = reg.counter(
            "prefill_chunks_total", "fixed-shape chunk-prefill dispatches")
        self._m_prefix_hits = reg.counter(
            "prefix_cache_hits_total", "admissions that reused a shared prefix")
        self._m_prefix_reused = reg.counter(
            "prefix_tokens_reused_total",
            "prompt tokens served from shared prefix pages")
        self._m_spec_rounds = reg.counter(
            "spec_rounds_total", "speculative draft/verify rounds")
        self._m_spec_drafted = reg.counter(
            "spec_drafted_total", "draft tokens proposed")
        self._m_spec_accepted = reg.counter(
            "spec_accepted_total", "draft tokens accepted by f32 verify")
        self._m_spec_acc_rate = reg.gauge(
            "spec_acceptance_rate", "cumulative accepted/drafted ratio")
        self._m_retrace = reg.counter(
            "retrace_total",
            "jitted step-function (re)traces, by trace-time side effect",
            labelnames=("step",))
        self._m_finished = reg.counter(
            "requests_finished_total", "requests finished",
            labelnames=("reason",))
        self._m_tokens = reg.counter(
            "tokens_generated_total", "tokens committed to finished requests")
        self._m_syncs = reg.counter(
            "host_syncs_total", "device->host synchronizations",
            labelnames=("kind",))
        self._m_active = reg.gauge("active_slots", "slots bound to a request")
        self._m_arb = reg.counter(
            "arbiter_switches_total", "slot-arbiter rung switches",
            labelnames=("arbiter", "cause"))
        self._m_tick_s = reg.histogram(
            "tick_seconds", "decode-tick phase wall time (s)",
            labelnames=("phase",), buckets=tb)
        self._m_prefill_s = reg.histogram(
            "prefill_seconds", "admission prefill wall time (s)", buckets=tb)
        self._m_req_latency = reg.histogram(
            "request_latency_seconds", "admission->finish wall time (s)",
            buckets=tb)

    def _make_switch_hook(self, arbiter_name: str, rung_names):
        """Observer for :attr:`SlotArbiter.on_switch`: promotes every
        rung switch to ``arbiter_switches_total{arbiter,cause}`` plus a
        trace instant on the slot's lane."""
        def hook(step, slot, old_idx, new_idx, cause):
            self._m_arb.inc(arbiter=arbiter_name, cause=cause)
            if self.telemetry.on:
                self.telemetry.instant(
                    "arbiter-switch", tid=slot + 1, args={
                        "arbiter": arbiter_name, "cause": cause,
                        "from": rung_names[old_idx], "to": rung_names[new_idx],
                        "step": step,
                    })
        return hook

    @property
    def stats(self) -> Dict[str, int]:
        """The historical counting-hook dict, now a read-only view of
        the registry (same keys/values as the pre-telemetry ad-hoc
        ``stats`` attribute)."""
        return {
            "decode_steps": int(self._m_decode_ticks.value()),
            "level_passes": int(self._m_level_passes.total()),
            "prefills": int(self._m_prefills.value()),
            "spec_rounds": int(self._m_spec_rounds.value()),
            "spec_drafted": int(self._m_spec_drafted.value()),
            "spec_accepted": int(self._m_spec_accepted.value()),
            "prefill_chunks": int(self._m_prefill_chunks.value()),
            "prefix_hits": int(self._m_prefix_hits.value()),
            "prefix_tokens_reused": int(self._m_prefix_reused.value()),
        }

    @property
    def _chunk_traces(self) -> int:
        """Trace-time counter for the fixed-shape chunk-prefill step —
        pinned by the zero-retrace test: after warmup it must not move,
        whatever mix of prompt lengths is admitted.  Alias for
        ``retrace_total{step="chunk"}``."""
        return int(self._m_retrace.value(step="chunk"))

    def metrics_snapshot(self) -> dict:
        """Point-in-time nested dict of every metric (refreshes the
        page-pool occupancy gauges first)."""
        if self.paged:
            self.cache_ops.scrape_gauges()
        self._m_active.set(len(self.scheduler.active_slots()))
        return self.telemetry.registry.snapshot()

    def render_prometheus(self) -> str:
        """Prometheus text exposition of :meth:`metrics_snapshot`."""
        if self.paged:
            self.cache_ops.scrape_gauges()
        self._m_active.set(len(self.scheduler.active_slots()))
        return self.telemetry.render_prometheus()

    # -- jitted step functions ---------------------------------------------

    def _build(self):
        cfg = self.cfg
        temperature = self.scfg.temperature

        def make_prefill(mode):
            def fn(params, tokens, caches):
                # trace-time side effect: fires when jit (re)traces this
                # body, never at run time — the retrace detector
                self._m_retrace.inc(step="prefill")
                return prefill_step(params, tokens, caches, cfg, mode=mode)
            return fn

        def make_decode(mode):
            # lane_mask zeroes non-member lanes so a pass's input tensor
            # (and therefore the FAST path's per-tensor activation
            # exponents) is independent of the other slots' contents —
            # the slot-isolation contract (see models.decode_step).
            def fn(params, tok, pos, caches, lane_mask):
                self._m_retrace.inc(step="decode")
                return decode_step(
                    params, tok, pos, caches, cfg, mode=mode, lane_mask=lane_mask
                )
            return fn

        self.engine.register(
            "prefill", **{lv: make_prefill(m) for lv, m in SERVE_STEP_LEVELS}
        )
        self.engine.register(
            "decode", **{lv: make_decode(m) for lv, m in SERVE_STEP_LEVELS}
        )
        pre_disp, _ = self.engine.switched("prefill", levels=self.level_names)
        dec_disp, _ = self.engine.switched("decode", levels=self.level_names)

        def merge_caches(old, new, mask):
            """Keep ``new`` cache rows only where ``mask`` is set."""
            def leaf(o, n):
                m = mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n.astype(o.dtype), o)
            return jax.tree.map(leaf, old, new)

        def mask_cache_view(caches, mask):
            """Non-member lanes see a PRISTINE cache: zero payloads,
            pos sentinel -1 (the same fill rule as the per-layer slot
            resets).  Without this, a masked lane attends to its own
            live cache (q=0 still averages the cached V rows),
            re-acquiring nonzero activations that leak into the FAST
            path's per-tensor activation exponents — the isolation
            contract would then depend on the neighbor's magnitudes.
            Fills are constants, so this holds no second pool alive."""
            def walk(node):
                out = {}
                for k, v in node.items():
                    if isinstance(v, dict):
                        out[k] = walk(v)
                    else:
                        m = mask.reshape((1, -1) + (1,) * (v.ndim - 2))
                        out[k] = jnp.where(m, v, jnp.asarray(-1 if k == "pos" else 0, v.dtype))
                return out
            return walk(caches)

        # per-request prefill: retraces per prompt LENGTH (exact-length,
        # no padding artifacts), never per level (traced switch index).
        # No donation: the zero single-request cache template is
        # allocated once and reused for every admission.
        self._prefill = jax.jit(pre_disp)
        self._single_template = init_caches(
            cfg, 1, self.scfg.max_len, dtype=SERVE_CACHE_DTYPE
        )

        def pool_pass(level_idx, params, tok, pos, caches, mask, logits_acc):
            """One decode pass of the whole pool at one level (the
            mixed-batch path): non-member lanes are zeroed at the input
            AND see a pristine cache view, so members compute exactly
            as if the other levels' slots were empty; cache rows and
            logits merge only where ``mask`` is set."""
            self._m_retrace.inc(step="pool_pass")
            view = mask_cache_view(caches, mask)
            logits, new_caches = dec_disp(level_idx, params, tok, pos, view, mask)
            caches = merge_caches(caches, new_caches, mask)
            logits_acc = jnp.where(mask[:, None], logits, logits_acc)
            return logits_acc, caches

        # NB: logits_acc is NOT donated — the zero accumulator template
        # is reused across steps and must stay valid.
        self._pool_pass = jax.jit(pool_pass, donate_argnums=(4,))

        def finish(logits, key):
            """Sample + per-slot health: [token, finite, amplitude].
            The (B, 3) view is pulled per step only in EOS mode; the
            async mode leaves it on device and folds it into the
            health accumulator."""
            if temperature <= 0:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(
                    key, jnp.asarray(logits, jnp.float32) / temperature, axis=-1
                ).astype(jnp.int32)
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            amp = jnp.max(jnp.abs(logits), axis=-1)
            host_view = jnp.stack(
                [tok.astype(jnp.float32), finite.astype(jnp.float32), amp], axis=1
            )
            return tok, host_view

        self._finish = jax.jit(finish)

        def step_update(gen_buf, gen_count, cur_tok, pos, health, tok, hv, active):
            """Fold one decode step's results into the device state:
            append active slots' tokens to their rings, advance their
            counts/positions, accumulate health — all without a host
            round-trip (inactive lanes write out-of-bounds -> dropped)."""
            B, L = gen_buf.shape
            idx = jnp.where(active, gen_count, L)
            gen_buf = gen_buf.at[jnp.arange(B), idx].set(tok, mode="drop")
            act = active.astype(jnp.int32)
            gen_count = gen_count + act
            cur_tok = jnp.where(active, tok, cur_tok)
            pos = pos + act
            health = jnp.where(
                active[:, None],
                jnp.stack(
                    [jnp.minimum(health[:, 0], hv[:, 1]),
                     jnp.maximum(health[:, 1], hv[:, 2])], axis=1,
                ),
                health,
            )
            return gen_buf, gen_count, cur_tok, pos, health

        self._step_update = jax.jit(step_update, donate_argnums=(0, 1, 2, 3, 4))

        def tick(level_idx, params, tok, pos, caches, mask, key,
                 gen_buf, gen_count, health):
            """Fused single-level decode step: pool pass + sampling +
            ring/health update in ONE dispatch, composed from the same
            ``finish``/``step_update``/``merge_caches`` bodies the
            mixed-level path jits separately.  The hot path when all
            active slots share a level (homogeneous traffic); its
            masked lanes are only EMPTY slots, whose cache rows the
            eviction reset already zeroed, so no pristine view is
            needed here."""
            self._m_retrace.inc(step="tick")
            logits, new_caches = dec_disp(level_idx, params, tok[:, None], pos, caches, mask)
            caches = merge_caches(caches, new_caches, mask)
            new_tok, hv = finish(logits, key)
            gen_buf, gen_count, tok, pos, health = step_update(
                gen_buf, gen_count, tok, pos, health, new_tok, hv, mask
            )
            return caches, gen_buf, gen_count, tok, pos, health, hv

        self._tick = jax.jit(tick, donate_argnums=(2, 3, 4, 7, 8, 9))

        # speculative per-slot mode: draft dispatch (traced rung index)
        # + fused f32 verify/commit, plus a ring update that appends a
        # VARIABLE number of committed tokens per slot in one dispatch.
        self._spec_draft = self._spec_verify = None
        if self.scfg.speculative is not None:
            k = self.scfg.speculative.k
            self._spec_draft, self._spec_verify, self._draft_levels = (
                register_spec_steps(self.engine, cfg, k)
            )

            def spec_update(gen_buf, gen_count, preds, n_commit, mask):
                B, L = gen_buf.shape
                rows = jnp.arange(B)
                for j in range(k + 1):  # static unroll: k+1 masked appends
                    w = mask & (j < n_commit)
                    idx = jnp.where(w, gen_count + j, L)
                    gen_buf = gen_buf.at[rows, idx].set(preds[:, j], mode="drop")
                return gen_buf, gen_count + n_commit

            self._spec_update = jax.jit(spec_update, donate_argnums=(0, 1))

        # cache lifecycle goes through the CacheOps surface.  The
        # contiguous ops are pure device functions -> jittable as-is;
        # the paged ops carry host bookkeeping (tables, refcounts) and
        # are driven un-jitted with jitted adapters (below).
        if not self.paged:
            ops = self.cache_ops
            self._write = jax.jit(ops.write, donate_argnums=(0,))
            self._reset = jax.jit(ops.reset, donate_argnums=(0,))
        else:
            self._write = self._reset = None
            self._build_paged(dec_disp, mask_cache_view, finish, step_update)
        self._zero_logits = jnp.zeros((self.scfg.n_slots, cfg.vocab), jnp.float32)
        self._health_neutral = jnp.tile(
            jnp.asarray([1.0, 0.0], jnp.float32), (self.scfg.n_slots, 1)
        )

    def _build_paged(self, dec_disp, mask_cache_view, finish, step_update):
        """The paged pool's jitted adapters: every step wraps the same
        level-switched bodies the contiguous path runs, between a
        block-table GATHER (pages -> the logical slot-contiguous view
        the model steps already consume) and a row/page SCATTER of
        exactly what the step wrote.  Block tables are jit ARGUMENTS —
        allocation/CoW/sharing change table content, never shapes, so
        the serving loop stays zero-retrace."""
        cfg = self.cfg
        pool: PagedCachePool = self.cache_ops
        C = self.scfg.resolved_chunk

        # chunked prefill: ONE fixed (1, C) segment shape for every
        # prompt length (the contiguous path's exact-length prefill
        # retraces per length; this is the tentpole's TTFT fix).  The
        # tail chunk keeps only its r valid rows: commit_segment rolls
        # the pad positions' writes back bit-for-bit (same rollback
        # machinery as speculative verify).
        def make_chunk(mode):
            def fn(params, tokens, positions, view, keep_pos, keep_count):
                # trace-time side effect (the zero-retrace counting hook)
                self._m_retrace.inc(step="chunk")
                logits, after, aux = segment_step(
                    params, tokens, positions, view, cfg, mode=mode
                )
                view = commit_segment(
                    after=after, before=view, seg_aux=aux, cfg=cfg,
                    keep_pos=keep_pos, keep_count=keep_count,
                    active=jnp.ones((1,), bool),
                )
                last = jnp.take_along_axis(
                    logits, jnp.clip(keep_count - 1, 0, C - 1).reshape(1, 1, 1),
                    axis=1,
                )[:, 0]
                return last, view
            return fn

        self.engine.register(
            "chunk", **{lv: make_chunk(m) for lv, m in SERVE_STEP_LEVELS}
        )
        chunk_disp, _ = self.engine.switched("chunk", levels=self.level_names)

        def chunk_admit(level_idx, params, tokens, positions, state,
                        slot_tables, scatter_ids, slot, keep_pos, keep_count):
            view = pool.slot_view(state, slot_tables, slot)
            last, view = chunk_disp(
                level_idx, params, tokens, positions, view, keep_pos, keep_count
            )
            return last, pool.slot_commit(state, scatter_ids, slot, view)

        self._chunk_admit = jax.jit(chunk_admit, donate_argnums=(4,))

        def tick_p(level_idx, params, tok, pos, state, tables, mask, key,
                   gen_buf, gen_count, health):
            """Paged homogeneous-level decode: gather -> fused step ->
            scatter the ONE row each active lane wrote.  Masked lanes
            are only empty slots here (zero tables -> pristine gather),
            mirroring the contiguous ``tick``."""
            self._m_retrace.inc(step="tick")
            view = pool.device_view(state, tables)
            logits, new_view = dec_disp(
                level_idx, params, tok[:, None], pos, view, mask
            )
            state = pool.commit_rows(state, tables, new_view, pos, mask)
            new_tok, hv = finish(logits, key)
            gen_buf, gen_count, tok, pos, health = step_update(
                gen_buf, gen_count, tok, pos, health, new_tok, hv, mask
            )
            return state, gen_buf, gen_count, tok, pos, health, hv

        self._tick_p = jax.jit(tick_p, donate_argnums=(2, 3, 4, 8, 9, 10))

        def pool_pass_p(level_idx, params, tok, pos, state, tables, mask,
                        logits_acc):
            """Paged mixed-level pass: other levels' lanes are LIVE in
            the page pool, so the gathered view is pristine-masked (the
            isolation contract) before the pass; their rows are dropped
            at the scatter."""
            self._m_retrace.inc(step="pool_pass")
            view = mask_cache_view(pool.device_view(state, tables), mask)
            logits, new_view = dec_disp(level_idx, params, tok, pos, view, mask)
            state = pool.commit_rows(state, tables, new_view, pos, mask)
            logits_acc = jnp.where(mask[:, None], logits, logits_acc)
            return logits_acc, state

        self._pool_pass_p = jax.jit(pool_pass_p, donate_argnums=(4,))

        if self.scfg.speculative is not None:
            k = self.scfg.speculative.k
            draft_j, verify_j = self._spec_draft, self._spec_verify

            def spec_draft_p(ri, params, tok, pos, state, tables, dmask):
                return draft_j(ri, params, tok, pos,
                               pool.device_view(state, tables), dmask)

            self._spec_draft_p = jax.jit(spec_draft_p)

            def spec_verify_p(params, tok, pos, drafts, state, tables, mask):
                """Verify + page-granular rollback: the committed view's
                k+1 segment rows carry accepted tokens' NEW bits and
                rejected positions' PRE-SEGMENT bits, so scattering all
                k+1 rows back restores rejected pages bit-for-bit."""
                view = pool.device_view(state, tables)
                preds, n_commit, view, new_tok, new_pos, finite, amp = verify_j(
                    params, tok, pos, drafts, view, mask
                )
                state = pool.commit_rows(
                    state, tables, view, pos, mask, n_rows=k + 1
                )
                return preds, n_commit, state, new_tok, new_pos, finite, amp

            self._spec_verify_p = jax.jit(spec_verify_p, donate_argnums=(4,))

    # -- admission / eviction ----------------------------------------------

    def _level_idx(self, req: Request) -> int:
        name = req.level or self.scfg.default_level
        if name not in self.level_names:
            raise ValueError(f"request {req.rid}: unknown level {name!r}")
        return self.level_names.index(name)

    def _admit(self, slot: int, req: Request) -> None:
        """Prefill the request at its own level and scatter its caches
        into the pool slot.  No host pull unless EOS checking needs the
        first token's value."""
        tel = self.telemetry
        plen = len(req.prompt)
        if req.speculative:
            # the exactness anchor: a speculative request's prefill and
            # (verify) decode both run the f32/"exact" rung; the
            # request-level rung choice moves to the DRAFT arbiter.
            li = self.level_names.index("f32")
            self.draft_arbiter.reset_slot(slot)
        else:
            li = self._level_idx(req)
        self.arbiter.reset_slot(slot, li)
        t0 = 0.0
        if tel.on:
            t0 = time.perf_counter()
            self._req_t0[slot] = t0
            tel.async_begin("request", id=req.rid, tid=slot + 1, args={
                "rid": req.rid, "prompt_len": plen,
                "level": self.level_names[li], "speculative": req.speculative,
            })
        with tel.span("admit", tid=slot + 1,
                      args={"rid": req.rid, "prompt_len": plen}
                      if tel.on else None):
            if self.paged:
                logits = self._prefill_chunked(slot, req.prompt, li)
            else:
                logits, single = self._prefill(
                    jnp.int32(li), self.params,
                    jnp.asarray([req.prompt], jnp.int32),
                    self._single_template,
                )
                self.pool = self._write(self.pool, single, slot)
            self._m_prefills.inc()
            self._key, sub = jax.random.split(self._key)
            tok, hv = self._finish(logits, sub)
            if tel.on and self.scfg.telemetry.sync_device:
                hv = jax.block_until_ready(hv)
        if tel.on:
            self._m_prefill_s.observe(time.perf_counter() - t0)
        self._tok = self._tok.at[slot].set(tok[0])
        self._pos = self._pos.at[slot].set(plen)
        self._gen_buf = self._gen_buf.at[slot, 0].set(tok[0])
        self._gen_count = self._gen_count.at[slot].set(1)
        self._health = self._health.at[slot].set(
            jnp.stack([hv[0, 1], hv[0, 2]])
        )
        eos_seen = False
        if self.scfg.eos_id is not None:
            self._m_syncs.inc(kind="eos")
            eos_seen = int(np.asarray(hv)[0, 0]) == self.scfg.eos_id
        self._m_active.set(len(self.scheduler.active_slots()))
        reason = self.scheduler.advance(slot, eos=eos_seen)
        if reason is not None:
            self._finish_slot(slot, reason)

    def _prefill_chunked(self, slot: int, prompt: List[int], li: int):
        """Paged admission: prefix-match + attach shared pages, then
        feed the unmatched tail through the fixed-shape chunk step —
        every admission costs ``ceil(tail / C)`` dispatches of ONE
        compiled executable regardless of prompt length (the contiguous
        path compiles per distinct length), and a decode tick can run
        between chunks of later admissions.  Returns the last-token
        logits (1, vocab) for first-token sampling."""
        pool: PagedCachePool = self.cache_ops
        self.pool, matched, chain = pool.prepare_admission(self.pool, slot, prompt)
        if matched:
            self._m_prefix_hits.inc()
            self._m_prefix_reused.inc(matched)
        C = self.scfg.resolved_chunk
        plen = len(prompt)
        li_dev = jnp.int32(li)
        slot_dev = jnp.int32(slot)
        # tables are fully allocated by prepare_admission -> constant
        # over the chunk loop
        slot_tables = pool.slot_tables(slot)
        scatter_ids = pool.scatter_ids(slot)
        last = None
        start = matched
        tel = self.telemetry
        while start < plen:
            r = min(C, plen - start)
            toks = np.zeros((1, C), np.int32)
            toks[0, :r] = prompt[start : start + r]
            positions = start + np.arange(C, dtype=np.int32)[None]
            with tel.span("prefill-chunk", tid=slot + 1,
                          args={"start": start, "rows": r} if tel.on else None):
                last, self.pool = self._chunk_admit(
                    li_dev, self.params, jnp.asarray(toks), jnp.asarray(positions),
                    self.pool, slot_tables, scatter_ids, slot_dev,
                    jnp.asarray([start + r - 1], jnp.int32),
                    jnp.asarray([r], jnp.int32),
                )
            self._m_prefill_chunks.inc()
            start += r
        # matched <= plen - 1 by construction (the block holding the
        # first decode write is never attached shared), so at least one
        # chunk always runs and `last` is real logits.
        assert last is not None
        pool.finish_admission(slot, chain, matched)
        return last

    def _finish_slot(self, slot: int, reason: str) -> FinishedRequest:
        """Pull the request's generated tokens (the one device->host
        transfer a request ever costs in async mode), record it
        finished, and reset the slot: zero cache rows (pos sentinel
        back to -1) so no KV/SSM state leaks into the next occupant."""
        n = self.scheduler.n_generated(slot)
        self._m_syncs.inc(kind="evict")
        toks = np.asarray(self._gen_buf[slot, :n]).tolist()
        fin = self.scheduler.finish(slot, toks, reason)
        self._m_finished.inc(reason=reason)
        self._m_tokens.inc(n)
        if self.telemetry.on:
            t0 = self._req_t0.pop(slot, None)
            if t0 is not None:
                self._m_req_latency.observe(time.perf_counter() - t0)
            self.telemetry.async_end("request", id=fin.rid, tid=slot + 1,
                                     args={"reason": reason, "n_generated": n})
        if self.paged:
            # release the slot's page references (shared pages survive in
            # the prefix cache) and zero its cumulative SSM lanes; page
            # PAYLOADS are not touched — allocation pristine-fills.
            self.pool = self.cache_ops.reset(self.pool, slot)
        else:
            self.pool = self._reset(self.pool, jnp.int32(slot))
        self._tok = self._tok.at[slot].set(0)
        self._pos = self._pos.at[slot].set(0)
        self._gen_count = self._gen_count.at[slot].set(0)
        self._m_active.set(len(self.scheduler.active_slots()))
        return fin

    # -- speculative round --------------------------------------------------

    def _spec_round(self, spec_now: np.ndarray, k: int) -> None:
        """One draft/verify round for the speculative lanes: draft k
        tokens per lane at each lane's DRAFT rung (grouped passes over
        the draft ladder, mask-merged like the vanilla multi-level
        path), verify all k+1 positions in one f32 segment pass that
        also commits/rolls back the pool in-dispatch, append the
        committed tokens to the device ring, and feed the measured
        acceptance rate to the draft arbiter.  The per-round host sync
        is (B, k+2) ints — commit counts + committed token values (the
        EOS/bookkeeping pull, the speculative analogue of the vanilla
        per-step (B, 3) pull)."""
        tel = self.telemetry
        tel_on = tel.on
        rungs = self.draft_arbiter.idx
        present = sorted(set(int(v) for v in rungs[spec_now]))
        tables = self.cache_ops.device_tables() if self.paged else None
        drafts = None
        with tel.span("draft", args={"rungs": len(present)} if tel_on else None):
            for ri in present:
                dmask = jnp.asarray(spec_now & (rungs == ri))
                if self.paged:
                    part = self._spec_draft_p(
                        jnp.int32(ri), self.params, self._tok, self._pos,
                        self.pool, tables, dmask,
                    )
                else:
                    part = self._spec_draft(
                        jnp.int32(ri), self.params, self._tok, self._pos, self.pool, dmask
                    )
                drafts = part if drafts is None else jnp.where(dmask[:, None], part, drafts)
        mask_dev = jnp.asarray(spec_now)
        with tel.span("verify", args={"k": k} if tel_on else None):
            if self.paged:
                (preds, n_commit, self.pool, self._tok, self._pos,
                 finite, amp) = self._spec_verify_p(
                    self.params, self._tok, self._pos, drafts, self.pool,
                    tables, mask_dev,
                )
            else:
                (preds, n_commit, self.pool, self._tok, self._pos,
                 finite, amp) = self._spec_verify(
                    self.params, self._tok, self._pos, drafts, self.pool, mask_dev
                )
            self._gen_buf, self._gen_count = self._spec_update(
                self._gen_buf, self._gen_count, preds, n_commit, mask_dev
            )
        # the per-round bookkeeping pull: commit counts + token values
        # (one logical sync, whatever mode)
        self._m_syncs.inc(kind="spec")
        n_h = np.asarray(n_commit)
        preds_h = np.asarray(preds)
        accepted = np.maximum(n_h - 1, 0)
        acc = np.where(spec_now, accepted / k, np.nan)
        self.draft_arbiter.observe(
            self._step, nonfinite=~np.asarray(finite), amplitude=np.asarray(amp),
            active=spec_now, acceptance=acc,
        )
        self._m_spec_rounds.inc()
        self._m_spec_drafted.inc(int(k * spec_now.sum()))
        self._m_spec_accepted.inc(int(accepted[spec_now].sum()))
        if self._m_spec_drafted.value():
            self._m_spec_acc_rate.set(
                self._m_spec_accepted.value() / self._m_spec_drafted.value()
            )
        eos_id = self.scfg.eos_id
        for slot in np.nonzero(spec_now)[0]:
            for j in range(int(n_h[slot])):
                eos = eos_id is not None and int(preds_h[slot, j]) == eos_id
                reason = self.scheduler.advance(int(slot), eos=eos)
                if reason is not None:
                    self._finish_slot(int(slot), reason)
                    break

    # -- the serving loop ---------------------------------------------------

    def serve(self, requests: Sequence[Request]) -> Dict[int, FinishedRequest]:
        """Run all requests to completion; returns {rid: FinishedRequest}.

        The loop structure is the continuous-batching engine: admission
        (per-request prefill into freed slots) interleaves with pool
        decode steps.  Host-sync policy: with ``eos_id`` set, one (B, 3)
        pull per step (token values are needed to detect EOS — the
        sanctioned per-token sync), and it carries the arbiter signals
        for free.  Without ``eos_id``, eviction times are deterministic
        from per-request budgets, so the loop dispatches fully async:
        tokens accumulate in the device ring and are pulled ONCE per
        request at eviction; health syncs every ``health_sync_every``
        steps (the arbiter's hysteresis then operates on that cadence).
        """
        # atomic submission: validate the whole batch (including
        # intra-batch rid collisions) before any request enters the
        # queue, so a bad request cannot strand its predecessors
        seen = set()
        for r in requests:
            self.scheduler.validate(r)
            if r.speculative and self._spec_verify is None:
                raise ValueError(
                    f"request {r.rid}: speculative=True but the server was "
                    "built without a speculative config"
                )
            if r.rid in seen:
                raise ValueError(f"duplicate request id {r.rid} within one serve() call")
            seen.add(r.rid)
        for r in requests:
            self.scheduler.submit(r)

        eos_mode = self.scfg.eos_id is not None
        wanted = [r.rid for r in requests]
        k = self.scfg.speculative.k if self.scfg.speculative is not None else 0
        mask_key, mask_dev = None, None  # device occupancy mask, uploaded on membership change
        can_admit = None
        if self.paged:
            # paged capacity predicate: FIFO admission stops while the
            # head request's worst-case block span exceeds free pages
            # (running requests release pages as they finish)
            can_admit = lambda r: self.cache_ops.can_admit(r.prompt)
        while self.scheduler.has_work():
            if can_admit is None:
                for slot, req in self.scheduler.admit():
                    self._admit(slot, req)
            else:
                # one admission per admit() call: _admit allocates the
                # request's pages, so the NEXT head's capacity check
                # must see the decremented free count (approving a
                # whole batch against one stale count over-commits)
                while True:
                    pairs = self.scheduler.admit(can_admit, limit=1)
                    if not pairs:
                        break
                    self._admit(*pairs[0])

            active = self.scheduler.active_mask()
            if not active.any():
                continue  # everything admitted finished at its first token

            # speculative lanes run their own draft/verify round; a
            # spec lane without segment headroom (pos + k would cross
            # max_len) falls back to a vanilla f32 step this iteration.
            spec_now = np.zeros_like(active)
            if self._spec_verify is not None:
                for s in np.nonzero(active)[0]:
                    if (self.scheduler.request_at(int(s)).speculative
                            and self.scheduler.position(int(s)) + k < self.scfg.max_len):
                        spec_now[s] = True
            van_now = active & ~spec_now

            if self.paged:
                # make this step's write targets physically backed:
                # vanilla lanes write one row at pos, spec lanes up to
                # k+1 rows — allocate missing blocks (and CoW shared
                # ones) BEFORE the jitted step reads the tables
                for s in np.nonzero(active)[0]:
                    p = self.scheduler.position(int(s))
                    hi = p + k if spec_now[s] else p
                    self.pool = self.cache_ops.ensure_rows(
                        self.pool, int(s), p, min(hi, self.scfg.max_len - 1)
                    )

            if spec_now.any():
                with self.telemetry.span(
                        "spec-round",
                        args={"step": self._step, "lanes": int(spec_now.sum())}
                        if self.telemetry.on else None):
                    self._spec_round(spec_now, k)

            if van_now.any():
                tel = self.telemetry
                tel_on = tel.on
                t0 = time.perf_counter() if tel_on else 0.0
                with tel.span("decode-tick",
                              args={"step": self._step,
                                    "lanes": int(van_now.sum())}
                              if tel_on else None):
                    levels = self.arbiter.idx
                    present = sorted(set(int(v) for v in levels[van_now]))
                    self._key, sub = jax.random.split(self._key)
                    tables = self.cache_ops.device_tables() if self.paged else None
                    t1 = time.perf_counter() if tel_on else 0.0
                    if len(present) == 1:
                        # hot path: homogeneous level -> ONE fused dispatch
                        key = (van_now.tobytes(), present[0])
                        if key != mask_key:
                            mask_key, mask_dev = key, jnp.asarray(van_now)
                        lv = self.level_names[present[0]]
                        with tel.span("level-pass",
                                      args={"level": lv} if tel_on else None):
                            if self.paged:
                                (self.pool, self._gen_buf, self._gen_count, self._tok,
                                 self._pos, self._health, hv) = self._tick_p(
                                    jnp.int32(present[0]), self.params, self._tok,
                                    self._pos, self.pool, tables, mask_dev, sub,
                                    self._gen_buf, self._gen_count, self._health,
                                )
                            else:
                                (self.pool, self._gen_buf, self._gen_count, self._tok,
                                 self._pos, self._health, hv) = self._tick(
                                    jnp.int32(present[0]), self.params, self._tok, self._pos,
                                    self.pool, mask_dev, sub,
                                    self._gen_buf, self._gen_count, self._health,
                                )
                        self._m_level_passes.inc(level=lv)
                    else:
                        # mixed levels: one pool pass per level, mask-merged
                        logits = self._zero_logits
                        for li in present:
                            mask = jnp.asarray(van_now & (levels == li))
                            lv = self.level_names[li]
                            with tel.span("level-pass",
                                          args={"level": lv} if tel_on else None):
                                if self.paged:
                                    logits, self.pool = self._pool_pass_p(
                                        jnp.int32(li), self.params, self._tok[:, None],
                                        self._pos, self.pool, tables, mask, logits,
                                    )
                                else:
                                    logits, self.pool = self._pool_pass(
                                        jnp.int32(li), self.params, self._tok[:, None], self._pos,
                                        self.pool, mask, logits,
                                    )
                            self._m_level_passes.inc(level=lv)
                        tok, hv = self._finish(logits, sub)
                        active_dev = jnp.asarray(van_now)
                        (self._gen_buf, self._gen_count, self._tok, self._pos,
                         self._health) = self._step_update(
                            self._gen_buf, self._gen_count, self._tok, self._pos,
                            self._health, tok, hv, active_dev,
                        )
                    self._m_decode_ticks.inc()
                    if tel_on and self.scfg.telemetry.sync_device:
                        # profiling mode ONLY: barrier so device_dispatch
                        # measures device time, not async dispatch time
                        hv = jax.block_until_ready(hv)
                    t2 = time.perf_counter() if tel_on else 0.0
                    self._step += 1

                    eos_flags = np.zeros((self.scfg.n_slots,), bool)
                    if eos_mode:
                        self._m_syncs.inc(kind="eos")
                        hv_host = np.asarray(hv)  # the per-step EOS pull
                        eos_flags = hv_host[:, 0].astype(np.int32) == self.scfg.eos_id
                        self.arbiter.observe(
                            self._step, nonfinite=hv_host[:, 1] < 0.5,
                            amplitude=hv_host[:, 2], active=van_now,
                        )
                    elif self._step % self.scfg.health_sync_every == 0:
                        self._m_syncs.inc(kind="health")
                        h = np.asarray(self._health)  # periodic aggregated sync
                        self.arbiter.observe(
                            self._step, nonfinite=h[:, 0] < 0.5, amplitude=h[:, 1],
                            active=van_now,
                        )
                        self._health = self._health_neutral.copy()  # template stays valid under donation

                    for slot in np.nonzero(van_now)[0]:
                        reason = self.scheduler.advance(int(slot), eos=bool(eos_flags[slot]))
                        if reason is not None:
                            self._finish_slot(int(slot), reason)
                if tel_on:
                    t3 = time.perf_counter()
                    self._m_tick_s.observe(t1 - t0, phase="host_schedule")
                    self._m_tick_s.observe(t2 - t1, phase="device_dispatch")
                    self._m_tick_s.observe(t3 - t2, phase="sync")
            else:
                self._step += 1

        # hand results out AND release them from the scheduler: a
        # server outlives its serve() calls, so retaining per-request
        # state forever would leak memory proportional to lifetime
        # traffic (a rid may be reused once its result is delivered).
        return {rid: self.scheduler.pop_finished(rid) for rid in wanted}

    def next_rid(self) -> int:
        """Fresh request id (the server outlives any one ``serve`` call
        — rids are unique for the server's lifetime)."""
        rid = self._rid_counter
        self._rid_counter += 1
        return rid

    def generate(self, prompts: List[List[int]], max_new: int = 32,
                 level: Optional[str] = None,
                 speculative: bool = False) -> List[List[int]]:
        """BatchedServer-compatible convenience: serve the prompts and
        return token lists in input order."""
        reqs = [
            Request(rid=self.next_rid(), prompt=list(p), max_new=max_new,
                    level=level, speculative=speculative)
            for p in prompts
        ]
        fins = self.serve(reqs)
        return [fins[r.rid].tokens for r in reqs]
