"""Logical-axis sharding rules (MaxText-style), with auto-drop.

Models declare *logical* axes on every parameter/activation dimension
('embed', 'heads', 'mlp', 'vocab', 'expert', 'ssm', 'batch', ...);
a RuleSet maps them to mesh axes per deployment:

TRAIN   — DP over (pod, data); TP over model for heads/mlp/vocab/ssm;
          FSDP: 'embed' -> data so params + optimizer state are fully
          2D-sharded (a 35B dense or 141B MoE train state fits).
SERVE   — weights replicated over data except the 'expert' axis of MoE
          weights (weight memory dominates); caches batch-over-data,
          heads-over-model.

Auto-drop: if a dimension is not divisible by the mapped mesh axes'
size, the mapping is dropped (replicated) instead of relying on uneven
GSPMD padding — memory stays predictable and every (arch x shape x
mesh) cell lowers.  Drops are recorded for the dry-run report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import Spec

__all__ = ["RuleSet", "train_rules", "serve_rules", "spec_sharding", "tree_shardings", "batch_pspec"]

AxisMap = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass
class RuleSet:
    rules: Dict[str, AxisMap]
    mesh: Mesh
    dropped: list = dataclasses.field(default_factory=list)

    def _axis_size(self, names: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[n] for n in names]))

    def resolve(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        out = []
        used = set()
        for dim, ax in zip(shape, axes):
            mapped = self.rules.get(ax) if ax is not None else None
            if mapped is None:
                out.append(None)
                continue
            names = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            names = tuple(n for n in names if n in self.mesh.shape and n not in used)
            if not names or dim % self._axis_size(names) != 0:
                if names:
                    self.dropped.append((ax, tuple(shape), names))
                out.append(None)
                continue
            used.update(names)
            out.append(names[0] if len(names) == 1 else names)
        return P(*out)


def train_rules(mesh: Mesh, fsdp: bool = True, pure_fsdp: bool = False) -> RuleSet:
    """Default: TP over 'model' + FSDP over 'data' (Megatron-style 2D).

    ``pure_fsdp``: NO tensor parallelism — every mesh axis is data
    parallel, parameters/optimizer state fully sharded over all axes
    (ZeRO-3).  Collectives become per-layer weight all-gathers instead
    of per-layer activation all-reduces; wins whenever
    ``layer_params << tokens_per_device x d_model`` (§Perf H2).
    """
    if pure_fsdp:
        return RuleSet(
            rules={
                "batch": ("pod", "data", "model"),
                "embed": ("data", "model"),
                "heads": None,
                "kv": None,
                "mlp": None,
                "vocab": None,
                "expert": None,
                "ssm": None,
                "seq": None,
                "layer": None,
            },
            mesh=mesh,
        )
    return RuleSet(
        rules={
            "batch": ("pod", "data"),
            "embed": "data" if fsdp else None,
            "heads": "model",
            "kv": "model",
            "mlp": "model",
            "vocab": "model",
            "expert": None,        # TP-within-expert (see models/moe.py)
            "ssm": "model",
            "seq": "model",        # SP on residuals between periods
            "layer": None,
        },
        mesh=mesh,
    )


def serve_rules(mesh: Mesh, expert_data_shard: bool = True, weight_fsdp: bool = False) -> RuleSet:
    """``weight_fsdp`` shards the 'embed' dim of weights over data —
    used when bf16 weights exceed per-device HBM under model-sharding
    alone (mixtral 141B: 17.6 GiB/dev replicated -> 1.1 GiB 2D-sharded;
    the per-layer weight all-gather cost shows up in the collective
    term, which is the honest trade for serving MoEs this large on a
    16x16 slice)."""
    return RuleSet(
        rules={
            "batch": ("pod", "data"),
            "embed": "data" if weight_fsdp else None,
            "heads": "model",
            "kv": "model",
            "mlp": "model",
            "vocab": "model",
            "expert": ("pod", "data") if expert_data_shard else None,
            "ssm": "model",
            "seq": None,
            "layer": None,
        },
        mesh=mesh,
    )


def spec_sharding(spec: Spec, rs: RuleSet) -> NamedSharding:
    return NamedSharding(rs.mesh, rs.resolve(spec.axes, spec.shape))


def tree_shardings(specs, rs: RuleSet):
    """pytree of Spec -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda s: spec_sharding(s, rs), specs, is_leaf=lambda x: isinstance(x, Spec)
    )


def batch_pspec(rs: RuleSet, batch_size: int, extra_dims: int = 1) -> P:
    """PartitionSpec for a (B, ...) array: batch over (pod, data) with
    auto-drop for tiny batches (long_500k B=1 -> replicated)."""
    names = tuple(n for n in ("pod", "data") if n in rs.mesh.shape)
    if not names or batch_size % int(np.prod([rs.mesh.shape[n] for n in names])) != 0:
        # try data alone before giving up
        if "data" in rs.mesh.shape and batch_size % rs.mesh.shape["data"] == 0:
            names = ("data",)
        else:
            return P(*([None] * (1 + extra_dims)))
    spec = names if len(names) > 1 else names[0]
    return P(spec, *([None] * extra_dims))
