"""The dynamic precision arbiter in action, ladder edition: train at a
cheap rung until numerics degrade (injected), escalate one rung at a
time through the two-phase barrier — or jump straight to f32 on a NaN —
then step back down after a stable window.  The paper's 'explicit,
safe, costless' mode choice made automatic, across FOUR tiers instead
of two.

Run:  PYTHONPATH=src python examples/precision_arbiter_demo.py
"""

from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import MathEngine


def main():
    ladder = ("q8_8", "q16_16", "q8_24", "f32")
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=4.0, stable_steps=6, cooldown_steps=2,
        ladder=ladder, start_mode="q8_8",
    ))
    eng = MathEngine("q8_8")

    # healthy steps, then a gradient spike (one rung up), then a NaN
    # (straight to the top), then a long recovery (stepwise back down)
    telemetry = [(s, 2.0 - 0.01 * s, 1.0) for s in range(10)]
    telemetry += [(10, 1.9, 40.0)]                      # spike!
    telemetry += [(11, float("nan"), 1.0)]              # NaN!
    telemetry += [(s, 1.8 - 0.004 * s, 1.0) for s in range(12, 60)]

    for step, loss, gnorm in telemetry:
        rec = arb.observe(step, loss, gnorm)
        if rec is not None:
            us = eng.set_level(rec)
            reason = arb.decisions[-1][2]
            print(f"step {step:3d}: -> {str(rec).upper():8s} ({reason})  barrier {us:.1f} us")
    print(f"\ndecision log: {arb.decisions}")
    print(f"engine level at end: {eng.level.name} (rung {arb.rung} of {len(ladder) - 1})")


if __name__ == "__main__":
    main()
