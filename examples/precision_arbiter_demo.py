"""The dynamic precision arbiter in action: train FAST until numerics
degrade (injected), fall back to PRECISE through the two-phase barrier,
then promote back to FAST after a stable window — the paper's
'explicit, safe, costless' mode choice made automatic.

Run:  PYTHONPATH=src python examples/precision_arbiter_demo.py
"""

from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import MathEngine, Mode


def main():
    arb = PrecisionArbiter(ArbiterConfig(spike_factor=4.0, stable_steps=6, cooldown_steps=2))
    eng = MathEngine(Mode.FAST)

    # healthy steps, then a gradient spike, then recovery
    telemetry = [(s, 2.0 - 0.01 * s, 1.0) for s in range(10)]
    telemetry += [(10, 1.9, 40.0)]                      # spike!
    telemetry += [(s, 1.9 - 0.005 * s, 1.0) for s in range(11, 30)]

    for step, loss, gnorm in telemetry:
        rec = arb.observe(step, loss, gnorm)
        if rec is not None:
            us = eng.set_mode(rec)
            reason = arb.decisions[-1][2]
            print(f"step {step:3d}: -> {rec.value.upper():8s} ({reason})  barrier {us:.1f} us")
    print(f"\ndecision log: {arb.decisions}")
    print(f"engine mode at end: {eng.mode.value}")


if __name__ == "__main__":
    main()
