"""Quickstart: the Dynamic Precision Math Engine public API.

Reproduces the paper's usage model (§4.4): one engine, two execution
paths, O(1) runtime switching — on tensors instead of scalars.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    MathEngine, Mode, Q16_16,
    to_fixed, from_fixed, q_mul, cordic_sincos,
    quantize_pow2, dequantize_pow2, static_footprint_bytes,
)
from repro.kernels.cordic import ops as cordic_ops
from repro.kernels.qmatmul import ops as qm_ops


def main():
    # --- paper C1: Q16.16 scalars on the integer pipeline ----------------
    a, b = to_fixed(3.25), to_fixed(-1.5)
    print("Q16.16 3.25 * -1.5 =", float(from_fixed(q_mul(a, b))))  # -4.875

    # --- paper C2: CORDIC sincos, 64-byte table, 16 iterations -----------
    theta = np.linspace(-np.pi, np.pi, 8).astype(np.float32)
    s, c = cordic_sincos(theta)
    print("max |cordic - libm| =", float(np.max(np.abs(np.asarray(s) - np.sin(theta)))))

    # --- paper C3: tiled int8 matmul with deferred rescale (Pallas) ------
    rng = np.random.default_rng(42)
    x = rng.uniform(-1, 1, (128, 256)).astype(np.float32)
    w = rng.uniform(-1, 1, (256, 128)).astype(np.float32)
    y = qm_ops.qmatmul(x, w)           # W8A8, ONE rounding event per element
    err = np.abs(np.asarray(y) - x @ w).max()
    print(f"qmatmul vs float: max err {err:.4f} (int8 grid)")

    # --- paper C4: runtime switching, dispatch table D --------------------
    eng = MathEngine(Mode.PRECISE)
    print("precise sin(0.5) =", float(eng.call("sin", np.float32(0.5))))
    us = eng.set_mode(Mode.FAST)       # two-phase barrier, O(1)
    print(f"switched to FAST in {us:.1f} us")
    print("fast    sin(0.5) =", float(eng.call("sin", np.float32(0.5))))

    # --- beyond the paper: the precision LADDER ---------------------------
    # FAST/PRECISE are compat aliases into a registry of named levels;
    # scoped dispatch + per-op policies pick a rung per operation.
    from repro.core import PrecisionPolicy, ladder_names

    print("ladder:", " < ".join(ladder_names()))
    with eng.at("q8_24"):              # scoped: Q8.24 CORDIC datapaths
        print("q8_24   sin(0.5) =", float(eng.call("sin", np.float32(0.5))))
    pol = PrecisionPolicy(default="q16_16", per_op={"atan2": "q8_24"})
    with eng.at(pol):                  # per-op: trig high-precision, rest fast
        print("policy atan2(3,4) =", float(eng.call("atan2", np.float32(3), np.float32(4))))
    print("fast   div(10, 4) =", float(eng.call("div", np.float32(10), np.float32(4))))

    # --- the 88-byte static footprint (paper §4.3.2) ----------------------
    print("static footprint:", static_footprint_bytes())

    # --- RoPE tables more accurate than fp32 at 500k positions ------------
    from repro.core.cordic import rope_inv_freq_q64
    f_hi, f_lo = rope_inv_freq_q64(128)
    sin_t, cos_t = cordic_ops.rope_tables(np.array([524287], np.uint32), f_hi, f_lo)
    print("rope table at pos 524287:", np.asarray(sin_t)[0, :3])


if __name__ == "__main__":
    main()
