"""End-to-end driver: train a ~100M-parameter decoder LM for a few
hundred steps on synthetic data, with the precision arbiter switching
between the paper's FAST (Q-format int8) and PRECISE (bf16) paths.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300] [--tiny]
"""

import argparse
import dataclasses

from repro.configs import get_config, smoke
from repro.core.arbiter import ArbiterConfig
from repro.data.pipeline import DataConfig
from repro.models.config import LayerSpec, ModelConfig
from repro.runtime.train_loop import Trainer, TrainerConfig


def lm_100m() -> ModelConfig:
    """~100M params: 12L, d=768, 12H, GQA kv=4, d_ff=2048, vocab=32768."""
    return ModelConfig(
        name="tiny-lm-100m", d_model=768, n_layers=12,
        period=(LayerSpec(kind="attn", window=None, ffn="mlp"),),
        vocab=32768, n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
        max_seq=512,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true", help="smoke-size model (CI)")
    ap.add_argument("--mode", default="fast",
                    choices=["fast", "precise", "q8_8", "q16_16", "q8_24", "f32"],
                    help="Mode compat alias or precision-ladder level name")
    args = ap.parse_args()

    cfg = smoke("deepseek_7b") if args.tiny else lm_100m()
    print(f"model: {cfg.name}  params: {cfg.param_count()/1e6:.1f}M")

    # binary compat aliases keep the classic FAST<->PRECISE arbiter; a
    # ladder level name gets the full multi-tier ladder so the arbiter's
    # start rung matches the engine's start level
    if args.mode in ("fast", "precise"):
        arb_cfg = ArbiterConfig()
    else:
        arb_cfg = ArbiterConfig(
            ladder=("q8_8", "q16_16", "q8_24", "f32"), start_mode=args.mode
        )
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 4, 1),
        ckpt_dir="/tmp/repro_tiny_lm",
        start_mode=args.mode,  # engine resolves aliases and level names alike
        use_arbiter=True,
        arbiter=arb_cfg,
    )
    data = DataConfig(vocab=cfg.vocab, seq_len=128 if not args.tiny else 32,
                      global_batch=8 if not args.tiny else 4)
    out = Trainer(cfg, tcfg, data_cfg=data).run()

    h = out["history"]
    for rec in h[:: max(len(h) // 20, 1)]:
        print(f"step {rec['step']:4d}  loss {rec['loss']:.4f}  "
              f"gnorm {rec['grad_norm']:.3f}  mode {rec['mode']}  {rec['dt']*1e3:.0f} ms")
    print(f"final loss: {out['final_loss']:.4f}  "
          f"switches: {out['switches']}  stragglers flagged: {len(out['straggler_events'])}")


if __name__ == "__main__":
    main()
