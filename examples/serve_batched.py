"""Batched serving example: prefill + lock-step decode with runtime
precision switching between requests (paper §7.2's hybrid strategy:
the engine picks the path per workload envelope).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import numpy as np

from repro.configs import smoke
from repro.core.precision import Mode
from repro.models import init_params
from repro.runtime.serve import BatchedServer, ServingConfig


def main():
    cfg = smoke("gemma2_2b")  # local/global alternating + softcaps
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, ServingConfig(n_slots=4, max_len=64, max_new=12))

    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5], [2, 4, 6, 8, 10, 12]]
    print("PRECISE generations:")
    for i, seq in enumerate(srv.generate(prompts)):
        print(f"  req{i}: {seq}")

    us = srv.set_mode(Mode.FAST)
    print(f"\nswitched to FAST (int8 W8A8) in {us:.0f} us (first switch compiles; later switches are O(1))")
    print("FAST generations:")
    for i, seq in enumerate(srv.generate(prompts)):
        print(f"  req{i}: {seq}")
    us = srv.set_mode(Mode.PRECISE)
    us = srv.set_mode(Mode.FAST)
    print(f"steady-state switch latency: {us:.1f} us (paper: 8.09 us on 240 MHz MCU)")


if __name__ == "__main__":
    main()
