"""Sensor-fusion demo: complementary-filter attitude estimation on the
precision ladder, with arbiter-driven multi-tier switching.

The workload the paper's engine was built for (§7.2 names trig on an
MCU), but using the ops a real IMU pipeline needs: ``atan2`` for the
accelerometer attitude and ``sqrt`` for the gravity-vector norm — both
dispatched through ``MathEngine``, so the SAME call sites run whatever
rung of the ladder is active (R1).

The attitude loop runs at the ``q8_24`` level via a
:class:`~repro.core.precision.PrecisionPolicy` — the angle-sensitive
``atan2`` gets the high-precision Q8.24 CORDIC datapath while the
gating ``sqrt`` stays on the cheaper Q16.16 path — and the demo
reports the attitude-accuracy delta of Q8.24 vs Q16.16 at the end.

A simulated pendulum swings while the gyro integrates angular rate and
the accelerometer provides the absolute (but noisy) reference; the
complementary filter blends them.  Mid-flight a vibration burst makes
the accelerometer telemetry spike; the PrecisionArbiter sees the
innovation blow up, steps up the ladder (q8_24 -> f32) through the
two-phase barrier, then steps back down after the configured stable
window.

Run:  PYTHONPATH=src python examples/sensor_fusion.py
"""

import math

import numpy as np

from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import MathEngine, PrecisionPolicy

DT = 0.01          # 100 Hz IMU
ALPHA = 0.98       # complementary-filter gyro weight
STEPS = 400
BURST = range(180, 200)  # vibration burst steps

#: the attitude policy: angle-sensitive atan2 at Q8.24, the |a| gate at
#: the cheap Q16.16 rung — per-op levels inside ONE context.
ATTITUDE_POLICY = PrecisionPolicy(default="q16_16", per_op={"atan2": "q8_24"})


def simulate_imu(rng):
    """True roll angle + gyro rate + accelerometer vector per step."""
    t = np.arange(STEPS) * DT
    roll = 0.6 * np.sin(2.0 * math.pi * 0.5 * t)            # rad
    rate = np.gradient(roll, DT)
    gyro = rate + rng.normal(0, 0.02, STEPS)                 # rad/s + noise
    ay = np.sin(roll) + rng.normal(0, 0.01, STEPS)           # g units
    az = np.cos(roll) + rng.normal(0, 0.01, STEPS)
    ax = rng.normal(0, 0.01, STEPS)
    for s in BURST:                                          # vibration burst
        ay[s] += rng.normal(0, 1.5)
        az[s] += rng.normal(0, 1.5)
    return roll, gyro, ax.astype(np.float32), ay.astype(np.float32), az.astype(np.float32)


def fuse(eng: MathEngine, arb, gyro, ax, ay, az):
    """One pass of the complementary filter through the engine's ops."""
    est = 0.0
    history, switches = [], []
    for s in range(STEPS):
        # accel attitude: roll = atan2(ay, az); also sanity-norm the
        # gravity vector with sqrt (a real pipeline gates on |a| ~ 1g)
        norm = float(eng.call("sqrt", np.float32(ax[s] ** 2 + ay[s] ** 2 + az[s] ** 2)))
        acc_roll = float(eng.call("atan2", np.float32(ay[s]), np.float32(az[s])))

        pred = est + gyro[s] * DT
        est = ALPHA * pred + (1.0 - ALPHA) * acc_roll
        history.append(est)

        if arb is None:
            continue
        # arbiter telemetry: innovation as "loss", |a|-deviation as the
        # spike channel (vibration shows up here first)
        innovation = abs(acc_roll - pred)
        rec = arb.observe(s, loss=innovation, grad_norm=abs(norm - 1.0) + 1e-3)
        if rec is not None:
            us = eng.set_level(rec)
            switches.append((s, rec, arb.decisions[-1][2], us))
    return np.array(history), switches


def run_fixed_level(level: str, gyro, ax, ay, az) -> np.ndarray:
    """The same filter pinned to one ladder rung (no arbiter)."""
    eng = MathEngine(level)
    est, _ = fuse(eng, None, gyro, ax, ay, az)
    return est


def main():
    rng = np.random.default_rng(42)
    roll, gyro, ax, ay, az = simulate_imu(rng)
    quiet = np.ones(STEPS, bool)
    quiet[list(BURST)] = False

    def rms(est):
        return float(np.sqrt(np.mean((est - roll)[quiet] ** 2)))

    # ---- the ladder payoff: attitude accuracy per trig level -------------
    # The filter itself is identical; only the atan2/sqrt datapath moves.
    est_q16 = run_fixed_level("q16_16", gyro, ax, ay, az)
    eng24 = MathEngine("q16_16")
    with eng24.at(ATTITUDE_POLICY):
        est_q24, _ = fuse(eng24, None, gyro, ax, ay, az)
    est_f32 = run_fixed_level("f32", gyro, ax, ay, az)
    r16, r24, r32 = rms(est_q16), rms(est_q24), rms(est_f32)
    print("attitude RMS error (quiet) by trig level:")
    print(f"  q16_16          : {r16:.7f} rad")
    print(f"  q8_24 (policy)  : {r24:.7f} rad")
    print(f"  f32             : {r32:.7f} rad")
    print(f"  q8_24 vs q16_16 : {r16 - r24:+.2e} rad "
          f"(residual vs f32: {abs(r24 - r32):.2e}; "
          f"Q8.24 removes ~{100.0 * (1.0 - abs(r24 - r32) / max(abs(r16 - r32), 1e-12)):.0f}% "
          f"of the fixed-point attitude error)")

    # ---- arbiter-driven run: q8_24 attitude loop, f32 rescue rung --------
    # innovation is a noisy, non-monotone signal: gate on grad-norm
    # spikes only (regress_tol=inf disables the loss-trend channel,
    # which would otherwise keep resetting the stability counter)
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=6.0, regress_tol=float("inf"),
        stable_steps=40, cooldown_steps=10,
        ladder=("q8_24", "f32"), start_mode="q8_24",
    ))
    eng = MathEngine("q8_24")
    est, switches = fuse(eng, arb, gyro, ax, ay, az)

    err = np.abs(est - roll)
    print(f"\narbitrated run (ladder q8_24 -> f32):")
    print(f"attitude RMS error (quiet): {rms(est):.7f} rad")
    print(f"attitude max error (burst): {err[~quiet].max():.5f} rad")
    for s, lvl, reason, us in switches:
        print(f"step {s:3d}: -> {str(lvl).upper():8s} ({reason})  barrier {us:.1f} us")
    print(f"engine level at end: {eng.level.name}")

    # both rungs agree to the documented FAST-path bounds on this task
    eng_q, eng_p = MathEngine("q8_24"), MathEngine("f32")
    a = float(eng_q.call("atan2", np.float32(0.31), np.float32(0.95)))
    b = float(eng_p.call("atan2", np.float32(0.31), np.float32(0.95)))
    print(f"atan2 q8_24 vs f32: {a:.7f} vs {b:.7f} (|d|={abs(a-b):.2e})")


if __name__ == "__main__":
    main()
