"""Sensor-fusion demo: complementary-filter attitude estimation on the
universal-CORDIC op family, with arbiter-driven precision switching.

The workload the paper's engine was built for (§7.2 names trig on an
MCU), but using the ops a real IMU pipeline needs: ``atan2`` for the
accelerometer attitude and ``sqrt`` for the gravity-vector norm — both
dispatched through ``MathEngine``, so the SAME call sites run the
Q16.16 universal-CORDIC path in FAST mode and the IEEE-754 path in
PRECISE mode (R1).

A simulated pendulum swings while the gyro integrates angular rate and
the accelerometer provides the absolute (but noisy) reference; the
complementary filter blends them.  Mid-flight a vibration burst makes
the accelerometer telemetry spike; the PrecisionArbiter sees the
innovation blow up, falls back to PRECISE through the two-phase
barrier, then promotes back to FAST after the configured stable window.

Run:  PYTHONPATH=src python examples/sensor_fusion.py
"""

import math

import numpy as np

from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import MathEngine, Mode

DT = 0.01          # 100 Hz IMU
ALPHA = 0.98       # complementary-filter gyro weight
STEPS = 400
BURST = range(180, 200)  # vibration burst steps


def simulate_imu(rng):
    """True roll angle + gyro rate + accelerometer vector per step."""
    t = np.arange(STEPS) * DT
    roll = 0.6 * np.sin(2.0 * math.pi * 0.5 * t)            # rad
    rate = np.gradient(roll, DT)
    gyro = rate + rng.normal(0, 0.02, STEPS)                 # rad/s + noise
    ay = np.sin(roll) + rng.normal(0, 0.01, STEPS)           # g units
    az = np.cos(roll) + rng.normal(0, 0.01, STEPS)
    ax = rng.normal(0, 0.01, STEPS)
    for s in BURST:                                          # vibration burst
        ay[s] += rng.normal(0, 1.5)
        az[s] += rng.normal(0, 1.5)
    return roll, gyro, ax.astype(np.float32), ay.astype(np.float32), az.astype(np.float32)


def fuse(eng: MathEngine, arb: PrecisionArbiter, gyro, ax, ay, az):
    """One pass of the complementary filter through the engine's ops."""
    est = 0.0
    history, switches = [], []
    for s in range(STEPS):
        # accel attitude: roll = atan2(ay, az); also sanity-norm the
        # gravity vector with sqrt (a real pipeline gates on |a| ~ 1g)
        norm = float(eng.call("sqrt", np.float32(ax[s] ** 2 + ay[s] ** 2 + az[s] ** 2)))
        acc_roll = float(eng.call("atan2", np.float32(ay[s]), np.float32(az[s])))

        pred = est + gyro[s] * DT
        est = ALPHA * pred + (1.0 - ALPHA) * acc_roll
        history.append(est)

        # arbiter telemetry: innovation as "loss", |a|-deviation as the
        # spike channel (vibration shows up here first)
        innovation = abs(acc_roll - pred)
        rec = arb.observe(s, loss=innovation, grad_norm=abs(norm - 1.0) + 1e-3)
        if rec is not None:
            us = eng.set_mode(rec)
            switches.append((s, rec.value, arb.decisions[-1][2], us))
    return np.array(history), switches


def main():
    rng = np.random.default_rng(42)
    roll, gyro, ax, ay, az = simulate_imu(rng)

    # innovation is a noisy, non-monotone signal: gate on grad-norm
    # spikes only (regress_tol=inf disables the loss-trend channel,
    # which would otherwise keep resetting the stability counter)
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=6.0, regress_tol=float("inf"),
        stable_steps=40, cooldown_steps=10, start_mode=Mode.FAST,
    ))
    eng = MathEngine(Mode.FAST)
    est, switches = fuse(eng, arb, gyro, ax, ay, az)

    err = np.abs(est - roll)
    quiet = np.ones(STEPS, bool)
    quiet[list(BURST)] = False
    print(f"attitude RMS error (quiet): {np.sqrt(np.mean(err[quiet]**2)):.5f} rad")
    print(f"attitude max error (burst): {err[~quiet].max():.5f} rad")
    for s, mode, reason, us in switches:
        print(f"step {s:3d}: -> {mode.upper():8s} ({reason})  barrier {us:.1f} us")
    print(f"engine mode at end: {eng.mode.value}")

    # both modes agree to the documented FAST-path bounds on this task
    eng_f, eng_p = MathEngine(Mode.FAST), MathEngine(Mode.PRECISE)
    a = float(eng_f.call("atan2", np.float32(0.31), np.float32(0.95)))
    b = float(eng_p.call("atan2", np.float32(0.31), np.float32(0.95)))
    print(f"atan2 FAST vs PRECISE: {a:.6f} vs {b:.6f} (|d|={abs(a-b):.2e})")


if __name__ == "__main__":
    main()
