"""Property-based-testing facade: real hypothesis when installed, a
vendored fixed-seed fallback otherwise.

The tier-1 suite must collect and pass in environments without
``hypothesis`` (minimal CI runners, air-gapped hosts), so test modules
import ``given`` / ``settings`` / ``strategies`` from here instead of
from ``hypothesis`` directly.  When hypothesis is importable, this
module is a pure re-export and behavior is identical.  Otherwise a
small shim drives each property with a deterministic example sweep:
the declared boundary values of every strategy first (paired
positionally, then a shuffled pairing so min/max cross-combinations
appear), then seeded-random draws up to ``max_examples``.

Only the subset this suite uses is implemented: ``strategies.integers``,
``strategies.floats``, ``strategies.booleans``, ``@given`` over
positional strategies, ``@settings(max_examples=...)``, and the
``settings.register_profile`` / ``settings.load_profile`` class API.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _SEED = 0x51DE  # fixed: the fallback is fully deterministic

    class _Strategy:
        """A value source: explicit boundary cases + seeded random draws."""

        def __init__(self, boundaries, draw):
            self.boundaries = list(boundaries)
            self._draw = draw

        def example(self, k, rng):
            if k < len(self.boundaries):
                return self.boundaries[k]
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            bounds = [min_value, max_value]
            for v in (0, 1, -1, min_value + 1, max_value - 1):
                if min_value <= v <= max_value and v not in bounds:
                    bounds.append(v)
            span = max_value - min_value

            def draw(rng):
                return int(min_value + rng.integers(0, span + 1))

            return _Strategy(bounds, draw)

        @staticmethod
        def floats(min_value=None, max_value=None, allow_nan=False, width=64):
            lo = -1e9 if min_value is None else float(min_value)
            hi = 1e9 if max_value is None else float(max_value)
            cast = (lambda v: float(np.float32(v))) if width == 32 else float
            bounds = [cast(lo), cast(hi)]
            for v in (0.0, lo / 2, hi / 2, lo + (hi - lo) * 1e-6):
                v = cast(v)
                if lo <= v <= hi and v not in bounds:
                    bounds.append(v)

            def draw(rng):
                return cast(lo + (hi - lo) * rng.random())

            return _Strategy(bounds, draw)

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda rng: bool(rng.integers(0, 2)))

    strategies = _StrategiesModule()

    class settings:  # noqa: N801 - mirrors hypothesis' API
        _profiles: dict = {}
        _current: dict = {"max_examples": 30}

        def __init__(self, **kw):
            self._kw = kw

        def __call__(self, fn):
            fn._pbt_max_examples = self._kw.get(
                "max_examples", self._current.get("max_examples", 30)
            )
            return fn

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._current = {**cls._current, **cls._profiles.get(name, {})}

    def given(*strats):
        """Drive the property over boundary combinations then random draws."""

        def deco(fn):
            max_ex = getattr(
                fn, "_pbt_max_examples", settings._current.get("max_examples", 30)
            )

            def runner():
                rng = np.random.default_rng(_SEED)
                n_bound = max(len(s.boundaries) for s in strats) if strats else 0
                # pass 1: boundaries paired positionally (min/min, max/max, ...)
                for k in range(min(n_bound, max_ex)):
                    fn(*(s.example(k, rng) for s in strats))
                # pass 2: shuffled boundary pairings (min/max cross-combos)
                for _ in range(min(n_bound, max(0, max_ex - n_bound))):
                    fn(*(s.boundaries[rng.integers(0, len(s.boundaries))] for s in strats))
                # pass 3: seeded random draws
                for _ in range(max(0, max_ex - 2 * n_bound)):
                    fn(*(s._draw(rng) for s in strats))

            # plain attribute copies only: functools.wraps would set
            # __wrapped__ and pytest would then see the original
            # signature and treat strategy params as fixtures
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
