"""Reusable exactness harness for ladder-speculative decoding — the
executable spec of the draft/verify contract (docs/speculative.md).

Three properties, checkable across model families x draft rungs x
seeds x draft lengths:

1. **Token exactness** (:meth:`ExactnessHarness.run_exactness`): the
   speculative token stream is token-for-token identical to vanilla
   f32 greedy decode.  Drafts influence only HOW FAST tokens are
   produced, never WHICH tokens.
2. **Cache rollback bit-identity**
   (:meth:`ExactnessHarness.run_rollback`): after a real speculative
   round (real drafts, real rejections), the committed cache pool is
   BIT-identical to what sequentially decoding only the accepted
   tokens would have produced, and every rejected position's entries
   are restored bit-for-bit to their pre-round contents.
3. **Acceptance accounting** (:func:`simulate_acceptance`): the
   decoder's drafted/accepted counters match a NumPy reference
   simulator replaying the per-round (drafts, verify argmax) trace.

The harness compiles each (family, k) combination ONCE and reuses it
across seeds and rungs — tests stay parametrization-wide without
paying per-case compiles.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_caches,
    init_params,
    prefill_step,
    segment_step,
    smoke_config,
    write_cache_slot,
)
from repro.runtime.speculative import (
    SPEC_CACHE_DTYPE,
    LadderSpeculativeDecoder,
    SpeculativeConfig,
)

#: families the spec suite sweeps: sliding-window local/global
#: attention (gemma2), hybrid attention+SSM+MoE (jamba), and latent
#: attention (minicpm3 MLA) — every cache kind the rollback must handle.
FAMILIES = ("gemma2_2b", "jamba_v01_52b", "minicpm3_4b")

DRAFT_RUNGS = ("q8_8", "q16_16")

#: fixed prompt-length pool: seeds vary CONTENT, not shapes, so the
#: per-family compile is paid once across the whole sweep.
PROMPT_LENS = (5, 9, 7)

MAX_LEN = 64


def family_config(name: str):
    mod = __import__(f"repro.configs.{name}", fromlist=["CONFIG"])
    return smoke_config(mod.CONFIG)


def make_prompts(vocab: int, seed: int) -> List[List[int]]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).tolist() for n in PROMPT_LENS]


# ---------------------------------------------------------------------------
# NumPy acceptance-accounting reference
# ---------------------------------------------------------------------------


def simulate_acceptance(trace: Sequence[dict], k: int) -> Dict[str, int]:
    """Replay a decoder trace (per round: drafts (B,k), preds (B,k+1),
    active (B,)) through plain NumPy and recompute the acceptance
    accounting from first principles: the accepted count of a lane is
    the length of the longest prefix where drafts == verify argmaxes.

    Returns {"rounds", "drafted", "accepted"} plus per-round commit
    counts under "n_commit" for cross-checking the decoder's own
    per-round numbers."""
    drafted = accepted = 0
    per_round: List[np.ndarray] = []
    for rec in trace:
        drafts = np.asarray(rec["drafts"])
        preds = np.asarray(rec["preds"])
        active = np.asarray(rec["active"], bool)
        B = drafts.shape[0]
        n_commit = np.zeros((B,), np.int64)
        for i in range(B):
            if not active[i]:
                continue
            m = 0
            while m < k and drafts[i, m] == preds[i, m]:
                m += 1
            n_commit[i] = m + 1
            drafted += k
            accepted += m
        per_round.append(n_commit)
    return {
        "rounds": len(per_round),
        "drafted": drafted,
        "accepted": accepted,
        "n_commit": per_round,
    }


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExactnessReport:
    family: str
    draft_level: str
    seed: int
    speculative: List[List[int]]
    vanilla: List[List[int]]
    acceptance_rate: float
    accounting: Dict[str, int]
    simulator: Dict[str, int]

    @property
    def tokens_ok(self) -> bool:
        return self.speculative == self.vanilla

    @property
    def accounting_ok(self) -> bool:
        return (self.accounting["drafted"] == self.simulator["drafted"]
                and self.accounting["accepted"] == self.simulator["accepted"])


class ExactnessHarness:
    """One compiled harness per (family, k): holds the model, the
    speculative decoders (one per draft rung, trace-collecting) and the
    jitted vanilla/segment reference steps."""

    def __init__(self, family: str, k: int = 3, eos_id: Optional[int] = None):
        self.family = family
        self.k = k
        self.eos_id = eos_id
        self.cfg = family_config(family)
        self.params = init_params(
            self.cfg, jax.random.PRNGKey(zlib.adler32(family.encode()) % (2**31))
        )
        self._decoders: Dict[str, LadderSpeculativeDecoder] = {}
        cfg = self.cfg
        self._pre = jax.jit(
            lambda pr, t, c: prefill_step(pr, t, c, cfg, mode="exact")
        )
        self._dec = jax.jit(
            lambda pr, t, p, c: decode_step(pr, t, p, c, cfg, mode="exact")
        )
        self._seg = jax.jit(
            lambda pr, t, p, c: segment_step(pr, t, p, c, cfg, mode="exact")
        )

    def decoder(self, draft_level: str) -> LadderSpeculativeDecoder:
        if draft_level not in self._decoders:
            self._decoders[draft_level] = LadderSpeculativeDecoder(
                self.cfg, self.params,
                SpeculativeConfig(
                    k=self.k, draft_level=draft_level, max_len=MAX_LEN,
                    eos_id=self.eos_id, collect_trace=True,
                ),
            )
        return self._decoders[draft_level]

    # -- property 1 + 3 ------------------------------------------------------

    def run_exactness(self, draft_level: str, seed: int,
                      max_new: int = 12) -> ExactnessReport:
        """Decode speculatively and vanilla from the same prompts;
        report token identity and acceptance accounting vs the NumPy
        simulator."""
        prompts = make_prompts(self.cfg.vocab, seed)
        dec = self.decoder(draft_level)
        trace_start = len(dec.trace)
        stats_before = dict(dec.stats)
        spec = dec.generate(prompts, max_new=max_new)
        accounting = {
            key: dec.stats[key] - stats_before[key]
            for key in ("rounds", "drafted", "accepted")
        }
        sim = simulate_acceptance(dec.trace[trace_start:], self.k)
        vanilla = self._vanilla(prompts, max_new)
        d = accounting["drafted"]
        return ExactnessReport(
            family=self.family, draft_level=draft_level, seed=seed,
            speculative=spec, vanilla=vanilla,
            acceptance_rate=accounting["accepted"] / d if d else float("nan"),
            accounting=accounting, simulator=sim,
        )

    def _vanilla(self, prompts, max_new: int) -> List[List[int]]:
        outs = []
        for p in prompts:
            caches = init_caches(self.cfg, 1, MAX_LEN, dtype=SPEC_CACHE_DTYPE)
            logits, caches = self._pre(
                self.params, jnp.asarray([list(p)], jnp.int32), caches
            )
            cur = int(jnp.argmax(logits, axis=-1)[0])
            toks = [cur]
            pos = len(p)
            while len(toks) < max_new:
                if self.eos_id is not None and cur == self.eos_id:
                    break
                logits, caches = self._dec(
                    self.params, jnp.asarray([[cur]], jnp.int32),
                    jnp.asarray([pos], jnp.int32), caches,
                )
                cur = int(jnp.argmax(logits, axis=-1)[0])
                toks.append(cur)
                pos += 1
            outs.append(toks)
        return outs

    # -- property 2 ----------------------------------------------------------

    def run_rollback(self, draft_level: str, seed: int) -> Dict[str, bool]:
        """One REAL speculative round (real drafts at the rung, real
        rejections), then two bit-level checks against the same
        pre-round cache state:

        * committed pool == sequentially decoding exactly the accepted
          tokens (bit-for-bit, every leaf) — since the sequential
          reference never touches the rejected positions at all, this
          also proves their entries were restored to their pre-round
          bits, not merely zeroed;
        * no position-indexed entry in the committed pool carries a
          position beyond the lane's last accepted one (rejected draft
          writes truly disappeared).
        """
        cfg = self.cfg
        k = self.k
        prompts = make_prompts(cfg.vocab, seed)
        B = len(prompts)
        dec = self.decoder(draft_level)

        caches = init_caches(cfg, B, MAX_LEN, dtype=SPEC_CACHE_DTYPE)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            single = init_caches(cfg, 1, MAX_LEN, dtype=SPEC_CACHE_DTYPE)
            logits, single = self._pre(
                self.params, jnp.asarray([list(p)], jnp.int32), single
            )
            caches = write_cache_slot(caches, single, jnp.int32(i))
            tok[i] = int(jnp.argmax(logits, axis=-1)[0])
            pos[i] = len(p)
        tok_d, pos_d = jnp.asarray(tok), jnp.asarray(pos)
        mask = jnp.ones((B,), bool)

        drafts = dec._draft(
            jnp.int32(dec.draft_levels.index(draft_level)),
            dec.params, tok_d, pos_d, caches, mask,
        )
        preds, n_commit, committed, _, _, _, _ = dec._verify(
            dec.params, tok_d, pos_d, drafts, caches, mask
        )
        n_h = np.asarray(n_commit)
        preds_h = np.asarray(preds)

        # reference: decode ONLY the accepted tokens sequentially.
        # lanes step one token at a time until each lane's commit count
        # is reached (lanes beyond their count are masked via where).
        ref = caches
        t = tok_d
        p_ = pos_d
        for j in range(int(n_h.max())):
            step_mask = jnp.asarray(j < n_h)
            _, stepped = self._dec(self.params, t[:, None], p_, ref)
            ref = jax.tree.map(
                lambda r, s: jnp.where(
                    step_mask.reshape((1, -1) + (1,) * (r.ndim - 2)),
                    s.astype(r.dtype), r,
                ),
                ref, stepped,
            )
            nxt = jnp.asarray(preds_h[np.arange(B), np.minimum(j, n_h - 1)])
            t = jnp.where(step_mask, nxt, t)
            p_ = p_ + step_mask.astype(jnp.int32)

        commit_eq = all(
            bool((a == b).all())
            for a, b in zip(jax.tree.leaves(committed), jax.tree.leaves(ref))
        )

        # no committed pos-indexed entry may sit beyond the lane's last
        # accepted position: rejected draft writes must have vanished
        keep_pos = pos + (n_h - 1)  # pos + m
        restored = True
        for key in committed:
            if not (isinstance(committed[key], dict) and "pos" in committed[key]):
                continue  # SSM caches are fully covered by commit_eq
            pc = np.asarray(committed[key]["pos"])        # (P, B, L)
            restored &= not (pc > keep_pos[None, :, None]).any()

        return {
            "commit_bit_identical": commit_eq,
            "rejected_restored": bool(restored),
            "had_rejections": bool((n_h < k + 1).any()),
        }
