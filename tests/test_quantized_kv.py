"""Q-format int8 KV cache (FAST serving): correctness vs the bf16 cache
and bounded quantization error — the paper's C1 applied to resident
serving state."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke
from repro.models import decode_step, init_caches, init_params, prefill_step


@pytest.mark.parametrize(
    "arch",
    [
        pytest.param(
            "deepseek_7b",
            marks=pytest.mark.xfail(
                reason="pre-existing: dense-GQA int8-KV logit error 0.73 > 0.45 bound "
                "on this toolchain.  Measured per-(layer, kv-head) dequant error is "
                "UNIFORM and already at the int8 pow2 floor (k: 0.40/0.41/0.64/0.71%, "
                "v: 0.69/0.66/0.65/0.40% of head amax; grid step is 0.39-0.79%), so "
                "finer per-head exponents cannot close it — the excess is cross-layer "
                "amplification of near-tied logits on the random-init smoke model "
                "(per-step logit diffs 0.12/0.11/0.73/0.20).  See ROADMAP "
                "'Known-failing tier-1 tests'",
                strict=False,
            ),
        ),
        "gemma2_2b",
        "mixtral_8x22b",
    ],
)
def test_quantized_decode_close_to_bf16(arch):
    """Greedy decode logits through the int8 cache track the bf16-cache
    logits within Q-format error (int8 grid ~ 0.8% of slot amax)."""
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(7))
    B, S = 2, 24
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))

    # teacher-forced: SAME token stream for both cache formats (greedy
    # feedback on a random-init model flips near-tied argmaxes and the
    # trajectories diverge chaotically — that would test chaos, not
    # quantization)
    forced = jnp.asarray(rng.integers(0, cfg.vocab, (4, B, 1)))
    outs = {}
    for quantized in (False, True):
        caches = init_caches(cfg, B, 64, quantized=quantized)
        logits, caches = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg))(
            params, toks, caches
        )
        pos = jnp.full((B,), S, jnp.int32)
        seq_logits = [np.asarray(logits, np.float32)]
        for i in range(4):
            logits, caches = jax.jit(lambda p, t, q, c: decode_step(p, t, q, c, cfg))(
                params, forced[i], pos, caches
            )
            seq_logits.append(np.asarray(logits, np.float32))
            pos = pos + 1
        outs[quantized] = np.stack(seq_logits)

    diff = np.abs(outs[True] - outs[False]).max()
    scale = np.abs(outs[False]).max()
    assert diff < 0.08 * scale + 0.15, (arch, diff, scale)


def test_kv_quantization_is_core_pow2_kept_axes():
    """The KV-cache quantizer IS quantize_pow2's kept-axes form: one
    exponent per (batch, seq, kv-head) slice, bit-identical payloads —
    cache quantization and weight/activation quantization share a
    single grid definition."""
    from repro.core.quantization import quantize_pow2
    from repro.models.attention import _q8

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 5, 3, 16)) * 10.0, jnp.float32)
    q, e = _q8(x, axes=(3,))
    assert q.dtype == jnp.int8 and e.shape == (2, 5, 3)
    qt = quantize_pow2(x, bits=8, axis=(0, 1, 2))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qt.q))
    np.testing.assert_array_equal(np.asarray(e), np.asarray(qt.exp).reshape(2, 5, 3))
    # per-head independence: rescaling ONE head leaves every other
    # head's payload and exponent untouched
    y = x.at[:, :, 1].multiply(64.0)
    q2, e2 = _q8(y, axes=(3,))
    np.testing.assert_array_equal(np.asarray(q2[:, :, [0, 2]]), np.asarray(q[:, :, [0, 2]]))
    np.testing.assert_array_equal(np.asarray(e2[:, :, [0, 2]]), np.asarray(e[:, :, [0, 2]]))
    np.testing.assert_array_equal(np.asarray(e2[:, :, 1]), np.asarray(e[:, :, 1]) + 6)
    # round-trip error bounded by half a grid step per head
    deq = np.asarray(q, np.float32) * np.exp2(np.asarray(e, np.float32))[..., None]
    amax = np.abs(np.asarray(x)).max(axis=3)
    assert (np.abs(deq - np.asarray(x)).max(axis=3) <= np.exp2(np.asarray(e)) / 2 + 1e-6).all()
    assert (amax / np.exp2(np.asarray(e, np.float64)) <= 127.0 + 0.5).all()


def test_quantized_cache_layout():
    cfg = smoke("deepseek_7b")
    c = init_caches(cfg, 2, 32, quantized=True)
    k = jax.tree.leaves({"k": c})[0]
    flat = jax.tree_util.tree_flatten_with_path(c)[0]
    names = {"/".join(str(getattr(p, "key", p)) for p in path) for path, _ in flat}
    assert any("k_exp" in n for n in names)
    # int8 payloads
    for path, leaf in flat:
        tail = str(getattr(path[-1], "key", path[-1]))
        if tail in ("k", "v"):
            assert leaf.dtype == jnp.int8, tail


def test_quantized_cache_halves_bytes():
    def nbytes(c):
        return sum(
            int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(c)
        )
    # smoke dims (hd=16): per-head exponent overhead is 4/16/2 = 12.5%
    cfg = smoke("deepseek_7b")
    full = nbytes(init_caches(cfg, 2, 64, quantized=False))
    quant = nbytes(init_caches(cfg, 2, 64, quantized=True))
    assert quant < 0.75 * full, (quant, full)

    # production dims (hd=128): overhead 1.6% -> true halving.
    # eval_shape only — no allocation of the 32k cache.
    from repro.configs import get_config
    prod = get_config("deepseek_7b")
    full_p = nbytes(jax.eval_shape(lambda: init_caches(prod, 8, 32768, quantized=False)))
    quant_p = nbytes(jax.eval_shape(lambda: init_caches(prod, 8, 32768, quantized=True)))
    assert quant_p < 0.53 * full_p, (quant_p, full_p)
