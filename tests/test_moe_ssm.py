"""Unit oracles for the two nontrivial mixers.

MoE: sort-based capacity dispatch vs a dense per-token oracle
(dropless regime) + conservation/drop properties.
SSD: chunked dual form vs the naive sequential recurrence, and
prefill-state -> decode-step consistency.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import LayerSpec, MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models.layers import init_from_specs
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


def tiny_moe_cfg(E=4, k=2, cf=8.0):
    return ModelConfig(
        name="tiny-moe", d_model=32, n_layers=1,
        period=(LayerSpec(kind="attn", ffn="moe"),),
        vocab=64, n_heads=2, n_kv_heads=2, head_dim=16, d_ff=48,
        moe=MoEConfig(num_experts=E, top_k=k, capacity_factor=cf),
    )


def dense_moe_oracle(params, x, cfg):
    """Route every token to its top-k experts with NO capacity limit."""
    from repro.models.layers import rms_norm

    B, S, d = x.shape
    h = np.asarray(rms_norm(x, params["norm"], cfg.rms_eps), np.float64)
    router = np.asarray(params["router"], np.float64)
    logits = h @ router
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    wg = np.asarray(params["w_gate"], np.float64)
    wu = np.asarray(params["w_up"], np.float64)
    wd = np.asarray(params["w_down"], np.float64)
    out = np.zeros_like(h)
    for b in range(B):
        for s in range(S):
            top = np.argsort(-p[b, s])[:k]
            gates = p[b, s, top] / p[b, s, top].sum()
            for e, g in zip(top, gates):
                a = h[b, s] @ wg[e]
                u = h[b, s] @ wu[e]
                act = (a / (1 + np.exp(-a))) * u  # silu(a) * u
                out[b, s] += g * (act @ wd[e])
    return out


def test_moe_matches_dense_oracle_dropless(rng):
    cfg = tiny_moe_cfg()
    params = init_from_specs(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 32)), jnp.float32)
    got, aux = jax.jit(lambda p, x: moe_mod.moe_forward(p, x, cfg))(params, x)
    want = dense_moe_oracle(params, x, cfg)
    # expert einsums run in bf16 (production dtype): ~2-3% tolerance
    np.testing.assert_allclose(np.asarray(got, np.float64), want, atol=0.4, rtol=0.05)
    assert np.isfinite(float(aux[0])) and float(aux[0]) > 0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor ~ 0, (almost) everything drops -> output ~ 0."""
    cfg = tiny_moe_cfg(cf=0.01)
    params = init_from_specs(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(0, 1, (1, 256, 32)), jnp.float32)
    got, _ = jax.jit(lambda p, x: moe_mod.moe_forward(p, x, cfg))(params, x)
    dense = dense_moe_oracle(params, x, cfg)
    # capacity 8 slots/expert vs 512 assignments: >90% dropped
    assert np.abs(np.asarray(got)).sum() < 0.2 * np.abs(dense).sum()


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


def naive_ssd_recurrence(x, dt, A, B_, C_):
    """Sequential oracle: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T."""
    Bb, S, nh, hd = x.shape
    ds = B_.shape[-1]
    x, dt, B_, C_ = (np.asarray(v, np.float64) for v in (x, dt, B_, C_))
    A = np.asarray(A, np.float64)
    y = np.zeros((Bb, S, nh, hd))
    h = np.zeros((Bb, nh, ds, hd))
    for t in range(S):
        decay = np.exp(dt[:, t, :] * A[None, :])          # (B,nh)
        inj = np.einsum("bd,bhp,bh->bhdp", B_[:, t], x[:, t], dt[:, t])
        h = h * decay[:, :, None, None] + inj
        y[:, t] = np.einsum("bd,bhdp->bhp", C_[:, t], h)
    return y


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(rng, chunk):
    Bb, S, nh, hd, ds = 2, 32, 3, 5, 7
    x = rng.normal(0, 1, (Bb, S, nh, hd)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (Bb, S, nh)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (nh,)).astype(np.float32)
    B_ = rng.normal(0, 1, (Bb, S, ds)).astype(np.float32)
    C_ = rng.normal(0, 1, (Bb, S, ds)).astype(np.float32)
    got, final = ssm_mod._ssd_chunked(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A), jnp.asarray(B_), jnp.asarray(C_),
        chunk=chunk,
    )
    want = naive_ssd_recurrence(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-3)


def test_ssd_final_state_continues_correctly(rng):
    """State after chunked(S tokens) + one recurrence step == chunked(S+1)."""
    Bb, S, nh, hd, ds = 1, 24, 2, 4, 6
    x = rng.normal(0, 1, (Bb, S + 1, nh, hd)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (Bb, S + 1, nh)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (nh,)).astype(np.float32)
    B_ = rng.normal(0, 1, (Bb, S + 1, ds)).astype(np.float32)
    C_ = rng.normal(0, 1, (Bb, S + 1, ds)).astype(np.float32)

    _, state = ssm_mod._ssd_chunked(
        jnp.asarray(x[:, :S]), jnp.asarray(dt[:, :S]), jnp.asarray(A),
        jnp.asarray(B_[:, :S]), jnp.asarray(C_[:, :S]), chunk=8,
    )
    # one decode step from the carried state
    decay = jnp.exp(jnp.asarray(dt[:, S]) * jnp.asarray(A)[None])
    inj = jnp.einsum("bd,bhp,bh->bhdp", jnp.asarray(B_[:, S]), jnp.asarray(x[:, S]), jnp.asarray(dt[:, S]))
    state2 = state * decay[:, :, None, None] + inj
    y_dec = jnp.einsum("bd,bhdp->bhp", jnp.asarray(C_[:, S]), state2)

    full, _ = ssm_mod._ssd_chunked(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A), jnp.asarray(B_), jnp.asarray(C_),
        chunk=8,
    )
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(full)[:, S], atol=1e-3, rtol=1e-3)
