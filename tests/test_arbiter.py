"""PrecisionArbiter hysteresis edge cases: cooldown vs flapping,
non-finite override, and promotion-counter resets.

Complements the happy-path policy tests in test_precision.py — these
pin the corner semantics the training loop relies on when numerics go
bad *during* a cooldown window.
"""

import math

from repro.core import ArbiterConfig, Mode, PrecisionArbiter


def warm(arb, steps, start=0, loss=1.0, gnorm=1.0):
    """Feed healthy telemetry so medians exist; returns the next step."""
    for s in range(start, start + steps):
        arb.observe(s, loss=loss, grad_norm=gnorm)
    return start + steps


# ---------------------------------------------------------------------------
# cooldown suppresses flapping
# ---------------------------------------------------------------------------


def test_cooldown_suppresses_spike_fallback_flapping():
    """After one FAST->PRECISE->FAST cycle, an immediate second spike
    inside the cooldown must NOT trip another fallback."""
    cfg = ArbiterConfig(spike_factor=4.0, stable_steps=2, cooldown_steps=10)
    arb = PrecisionArbiter(cfg)
    step = warm(arb, 16)

    assert arb.observe(step, loss=1.0, grad_norm=100.0) is Mode.PRECISE
    step += 1
    # ride out cooldown + stability -> promotion back to FAST
    while arb.mode is Mode.PRECISE:
        arb.observe(step, loss=1.0, grad_norm=1.0)
        step += 1
    promoted_at = step - 1

    # a spike immediately after the promotion is within the cooldown:
    # the arbiter must hold FAST (no flap), and only fall back once
    # the cooldown has elapsed
    for s in range(step, promoted_at + cfg.cooldown_steps):
        assert arb.observe(s, loss=1.0, grad_norm=100.0) is None, s
        assert arb.mode is Mode.FAST
    assert arb.observe(promoted_at + cfg.cooldown_steps, loss=1.0, grad_norm=100.0) is Mode.PRECISE


def test_cooldown_blocks_promotion():
    """stable_steps shorter than the cooldown: promotion waits for BOTH."""
    cfg = ArbiterConfig(spike_factor=2.0, stable_steps=1, cooldown_steps=40)
    arb = PrecisionArbiter(cfg)
    step = warm(arb, 16)
    assert arb.observe(step, loss=1.0, grad_norm=50.0) is Mode.PRECISE
    switch_step = step
    for s in range(step + 1, switch_step + cfg.cooldown_steps):
        assert arb.observe(s, loss=1.0, grad_norm=1.0) is None
        assert arb.mode is Mode.PRECISE
    assert arb.observe(switch_step + cfg.cooldown_steps, loss=1.0, grad_norm=1.0) is Mode.FAST


# ---------------------------------------------------------------------------
# non-finite loss overrides the cooldown
# ---------------------------------------------------------------------------


def test_nonfinite_forces_precise_inside_cooldown():
    cfg = ArbiterConfig(spike_factor=4.0, stable_steps=1, cooldown_steps=100)
    arb = PrecisionArbiter(cfg)
    step = warm(arb, 16)
    arb._last_switch_step = step - 1  # mid-cooldown by construction

    # a grad spike is suppressed by the cooldown...
    assert arb.observe(step, loss=1.0, grad_norm=500.0) is None
    assert arb.mode is Mode.FAST
    # ...but a NaN/inf loss is not
    assert arb.observe(step + 1, loss=float("nan"), grad_norm=1.0) is Mode.PRECISE
    assert arb.mode is Mode.PRECISE
    assert arb.decisions[-1][2] == "non-finite"


def test_nonfinite_inf_also_forces():
    cfg = ArbiterConfig(cooldown_steps=10**6)
    arb = PrecisionArbiter(cfg)
    step = warm(arb, 10)
    arb._last_switch_step = step - 1
    assert arb.observe(step, loss=math.inf, grad_norm=1.0) is Mode.PRECISE


def test_nonfinite_not_added_to_telemetry_window():
    """NaN steps must not poison the running medians."""
    arb = PrecisionArbiter(ArbiterConfig(cooldown_steps=0))
    step = warm(arb, 12)
    before = list(arb._losses)
    arb.observe(step, loss=float("nan"), grad_norm=1.0)
    assert list(arb._losses) == before


# ---------------------------------------------------------------------------
# stable_steps promotion counter resets on a new spike
# ---------------------------------------------------------------------------


def test_promotion_counter_resets_on_new_spike():
    cfg = ArbiterConfig(spike_factor=4.0, stable_steps=8, cooldown_steps=0)
    arb = PrecisionArbiter(cfg)
    step = warm(arb, 16)
    assert arb.observe(step, loss=1.0, grad_norm=100.0) is Mode.PRECISE
    step += 1

    # 6 healthy steps (not yet stable_steps=8) ...
    for _ in range(6):
        assert arb.observe(step, loss=1.0, grad_norm=1.0) is None
        step += 1
    # ... then a fresh spike: the counter must reset to zero
    assert arb.observe(step, loss=1.0, grad_norm=200.0) is None
    assert arb._stable == 0
    step += 1

    # promotion now needs the FULL stable window again, not just 2 more
    for i in range(cfg.stable_steps - 1):
        assert arb.observe(step, loss=1.0, grad_norm=1.0) is None, i
        step += 1
    assert arb.observe(step, loss=1.0, grad_norm=1.0) is Mode.FAST


def test_decision_log_records_reasons():
    arb = PrecisionArbiter(ArbiterConfig(spike_factor=4.0, cooldown_steps=0, stable_steps=2))
    step = warm(arb, 16)
    arb.observe(step, loss=1.0, grad_norm=99.0)
    assert arb.decisions[-1][1] is Mode.PRECISE
    assert "grad-spike" in arb.decisions[-1][2]
