"""Continuous-batching serving engine tests: scheduler admission/
eviction invariants, per-slot arbiter hysteresis, slot isolation (reuse
never leaks KV/SSM state across requests), and the mixed-precision
contract (per-slot levels behave identically to running each request
alone at its level)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _pbt import given, settings, strategies as st
from repro.configs import smoke
from repro.core.arbiter import SlotArbiter, SlotArbiterConfig
from repro.runtime.scheduler import ContinuousScheduler, Request
from repro.models import init_caches, init_params, prefill_step
from repro.runtime.serve import (
    ContinuousBatchingServer,
    ContinuousServerConfig,
    SERVE_STEP_LEVELS,
)


# ---------------------------------------------------------------------------
# scheduler (pure host logic)
# ---------------------------------------------------------------------------


def _req(rid, plen=4, max_new=4, level=None):
    return Request(rid=rid, prompt=list(range(1, plen + 1)), max_new=max_new, level=level)


def test_scheduler_fifo_admission_and_slot_binding():
    s = ContinuousScheduler(n_slots=2, max_len=32)
    for i in range(5):
        s.submit(_req(i))
    admitted = s.admit()
    assert [(slot, r.rid) for slot, r in admitted] == [(0, 0), (1, 1)]
    assert s.admit() == []                      # no free slots
    assert s.active_slots() == [0, 1]
    # finish slot 1 -> rid 2 (not 3) takes its place: FIFO
    assert s.advance(1) is None
    s.advance(1); s.advance(1)
    assert s.advance(1) == "max_new"
    s.finish(1, [9, 9, 9, 9], "max_new")
    admitted = s.admit()
    assert [(slot, r.rid) for slot, r in admitted] == [(1, 2)]


def test_scheduler_every_request_finishes_exactly_once():
    s = ContinuousScheduler(n_slots=3, max_len=64)
    for i in range(7):
        s.submit(_req(i, max_new=2 + i % 3))
    while s.has_work():
        s.admit()
        for slot in s.active_slots():
            reason = s.advance(slot)
            if reason is not None:
                n = s.n_generated(slot)
                s.finish(slot, [0] * n, reason)
    assert sorted(s.finished) == list(range(7))
    for i in range(7):
        assert s.finished[i].n_generated == 2 + i % 3


def test_scheduler_termination_reasons():
    s = ContinuousScheduler(n_slots=1, max_len=8, eos_id=99)
    s.submit(_req(0, plen=4, max_new=10))
    s.admit()
    assert s.advance(0, eos=False) is None
    assert s.advance(0, eos=True) == "eos"      # EOS beats budget
    s.finish(0, [1, 99], "eos")
    # max_len: prompt 4 + generated hits the window
    s.submit(_req(1, plen=6, max_new=10))
    s.admit()
    assert s.advance(0) is None                 # pos 7
    assert s.advance(0) == "max_len"            # pos 8 == max_len
    s.finish(0, [1, 2], "max_len")


def test_scheduler_rejects_bad_requests():
    s = ContinuousScheduler(n_slots=1, max_len=8)
    s.submit(_req(0))
    with pytest.raises(ValueError):
        s.submit(_req(0))                       # duplicate rid
    with pytest.raises(ValueError):
        s.submit(_req(1, plen=8))               # prompt fills the window
    with pytest.raises(ValueError):
        Request(rid=2, prompt=[], max_new=4)    # empty prompt
    with pytest.raises(ValueError):
        Request(rid=3, prompt=[1], max_new=0)   # no budget


# ---------------------------------------------------------------------------
# scheduler under random churn (property-based)
# ---------------------------------------------------------------------------
#
# A seeded driver throws random admission/eviction/escalation traffic at
# the scheduler and checks the invariants its docstring promises hold at
# EVERY step, not just on the happy path the unit tests walk.


def _run_churn(n_slots: int, n_requests: int, seed: int, max_len: int = 16):
    """Drive one random serving episode; assert step-level invariants;
    return (scheduler, requests, admission_order)."""
    rng = np.random.default_rng(seed)
    levels = ("q16_16", "f32")
    s = ContinuousScheduler(n_slots=n_slots, max_len=max_len, eos_id=99,
                            levels=levels)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(1, max_len - 1))
        reqs.append(Request(
            rid=i, prompt=[int(t) for t in rng.integers(0, 50, plen)],
            max_new=int(rng.integers(1, 6)),
            level=[None, *levels][int(rng.integers(0, 3))],
        ))
        s.submit(reqs[-1])

    admit_order = []
    live = {}                                     # slot -> rid (our shadow table)
    steps = 0
    while s.has_work():
        steps += 1
        assert steps < 10_000, "scheduler livelock"
        for slot, r in s.admit():
            assert slot not in live, "slot double-booked"   # no cache-row leak
            live[slot] = r.rid
            admit_order.append(r.rid)
        for slot in list(s.active_slots()):
            assert live[slot] == s.request_at(slot).rid     # binding is stable
            if rng.random() < 0.7:                # decode progress is ragged
                reason = s.advance(slot, eos=bool(rng.random() < 0.1))
                assert s.position(slot) <= max_len
                if reason is not None:            # eviction frees the row
                    n = s.n_generated(slot)
                    s.finish(slot, [0] * n, reason)
                    del live[slot]
    return s, reqs, admit_order


@settings(max_examples=20)
@given(st.integers(1, 4), st.integers(1, 12), st.integers(0, 10**6))
def test_scheduler_churn_invariants(n_slots, n_requests, seed):
    """Under arbitrary churn: FIFO admission, every request finished
    exactly once with a sane token count, and every slot freed."""
    s, reqs, admit_order = _run_churn(n_slots, n_requests, seed)
    assert admit_order == sorted(admit_order)     # FIFO fairness
    assert len(admit_order) == len(reqs)          # nobody starved
    assert sorted(s.finished) == list(range(len(reqs)))
    assert s.slots == [None] * n_slots            # all rows released
    for req in reqs:
        f = s.finished[req.rid]
        assert 1 <= f.n_generated <= req.max_new
        assert len(f.tokens) == len(req.prompt) + f.n_generated
        assert f.reason in ("eos", "max_new", "max_len")
        if f.reason == "max_len":
            assert len(f.tokens) == s.max_len
        if f.reason == "max_new":
            assert f.n_generated == req.max_new


@settings(max_examples=10)
@given(st.integers(1, 3), st.integers(0, 10**6))
def test_scheduler_rid_reuse_after_pop(n_slots, seed):
    """pop_finished releases the rid: the same id can be resubmitted
    and the second life is bookkept independently of the first."""
    s, reqs, _ = _run_churn(n_slots, 5, seed)
    for req in reqs:
        fin = s.pop_finished(req.rid)
        assert fin.rid == req.rid
    assert s.finished == {} and s._submitted == set()   # state fully drained
    s.submit(Request(rid=reqs[0].rid, prompt=[1, 2], max_new=1))
    s.admit()
    assert s.advance(0) == "max_new"
    assert s.finish(0, [7], "max_new").n_generated == 1


# ---------------------------------------------------------------------------
# per-slot arbiter
# ---------------------------------------------------------------------------


def test_slot_arbiter_nan_jumps_to_top_and_demotes_to_floor():
    cfg = SlotArbiterConfig(n_levels=3, start_idx=0, stable_steps=2, cooldown_steps=2)
    arb = SlotArbiter(4, cfg)
    arb.reset_slot(1, start_idx=1)              # slot 1's floor is rung 1
    nonf = np.array([True, True, False, False])
    idx = arb.observe(0, nonfinite=nonf, amplitude=np.zeros(4))
    assert list(idx) == [2, 2, 0, 0]            # NaN slots rescue to top, no cooldown
    # healthy steps demote one rung at a time — but never below floor
    step = 1
    for _ in range(20):
        idx = arb.observe(step, nonfinite=np.zeros(4, bool), amplitude=np.zeros(4))
        step += 1
    assert list(idx) == [0, 1, 0, 0]            # slot 1 stops at its floor


def test_slot_arbiter_amplitude_escalates_with_cooldown():
    cfg = SlotArbiterConfig(n_levels=3, start_idx=0, amp_threshold=10.0,
                            stable_steps=100, cooldown_steps=4)
    arb = SlotArbiter(2, cfg)
    amp = np.array([100.0, 0.0])
    idx = arb.observe(0, nonfinite=np.zeros(2, bool), amplitude=amp)
    assert list(idx) == [1, 0]                  # one rung, not a jump
    idx = arb.observe(1, nonfinite=np.zeros(2, bool), amplitude=amp)
    assert list(idx) == [1, 0]                  # cooldown blocks the next rung
    idx = arb.observe(5, nonfinite=np.zeros(2, bool), amplitude=amp)
    assert list(idx) == [2, 0]                  # cooled: next rung


def _acc_cfg(**kw):
    base = dict(n_levels=3, start_idx=0, accept_threshold=0.5,
                accept_patience=3, cooldown_steps=1, stable_steps=10**6)
    base.update(kw)
    return SlotArbiterConfig(**base)


def _quiet(n):
    return dict(nonfinite=np.zeros(n, bool), amplitude=np.zeros(n))


def test_slot_arbiter_acceptance_escalates_after_patience():
    """Sustained low draft acceptance steps the rung up — but only
    after accept_patience consecutive low measurements, and one healthy
    measurement resets the counter (no single-round flapping)."""
    arb = SlotArbiter(2, _acc_cfg())
    low = np.array([0.2, 0.9])
    for step in range(2):
        assert list(arb.observe(step, **_quiet(2), acceptance=low)) == [0, 0]
    # third consecutive low measurement trips the escalation
    assert list(arb.observe(2, **_quiet(2), acceptance=low)) == [1, 0]
    assert arb.switches[-1][-1] == "acceptance"
    # counter was reset by the switch: two lows don't re-trip...
    assert list(arb.observe(3, **_quiet(2), acceptance=low)) == [1, 0]
    assert list(arb.observe(4, **_quiet(2), acceptance=low)) == [1, 0]
    # ...and a good round mid-run resets the count entirely
    arb.observe(5, **_quiet(2), acceptance=np.array([0.8, 0.9]))
    assert list(arb.observe(6, **_quiet(2), acceptance=low)) == [1, 0]
    assert list(arb.observe(7, **_quiet(2), acceptance=low)) == [1, 0]
    assert list(arb.observe(8, **_quiet(2), acceptance=low)) == [2, 0]


def test_slot_arbiter_acceptance_cooldown_hysteresis():
    """With a long cooldown, a slot that just escalated must sit out
    the window even when low measurements keep accumulating."""
    arb = SlotArbiter(1, _acc_cfg(accept_patience=1, cooldown_steps=5))
    low = np.array([0.0])
    assert list(arb.observe(0, **_quiet(1), acceptance=low)) == [1]
    for step in range(1, 5):                     # inside the cooldown window
        assert list(arb.observe(step, **_quiet(1), acceptance=low)) == [1], step
    assert list(arb.observe(5, **_quiet(1), acceptance=low)) == [2]  # cooled


def test_slot_arbiter_acceptance_never_demotes_below_floor():
    """Acceptance is an ESCALATION-only signal: perfect acceptance never
    drops a slot below the rung its request asked for, and demotion (on
    stability) still stops at the floor."""
    arb = SlotArbiter(1, _acc_cfg(stable_steps=2, cooldown_steps=1))
    arb.reset_slot(0, start_idx=1)               # requested floor: rung 1
    perfect = np.array([1.0])
    for step in range(12):
        idx = arb.observe(step, **_quiet(1), acceptance=perfect)
        assert idx[0] >= 1, step                 # never below the floor
    assert arb.idx[0] == 1


def test_slot_arbiter_nan_rescue_takes_precedence_over_acceptance():
    """A non-finite logit on the same step as a tripped acceptance
    counter: the NaN rescue wins (correctness beats throughput) — jump
    to the TOP rung, reason 'non-finite', no one-rung step."""
    arb = SlotArbiter(1, _acc_cfg(accept_patience=1))
    idx = arb.observe(0, nonfinite=np.array([True]), amplitude=np.zeros(1),
                      acceptance=np.array([0.0]))
    assert list(idx) == [2]                      # top, not start+1
    assert arb.switches[-1][-1] == "non-finite"


def test_slot_arbiter_unmeasured_acceptance_leaves_counter_untouched():
    """NaN / negative acceptance marks 'no measurement this step'
    (vanilla lanes, inactive slots): the low-counter neither grows nor
    resets, so patience accumulates only over REAL measurements."""
    arb = SlotArbiter(1, _acc_cfg())
    low, nomeas = np.array([0.1]), np.array([np.nan])
    arb.observe(0, **_quiet(1), acceptance=low)
    arb.observe(1, **_quiet(1), acceptance=low)          # counter: 2
    for step in range(2, 6):                             # gaps don't reset it
        assert list(arb.observe(step, **_quiet(1), acceptance=nomeas)) == [0]
        assert list(arb.observe(step, **_quiet(1), acceptance=np.array([-1.0]))) == [0]
    assert list(arb.observe(6, **_quiet(1), acceptance=low)) == [1]  # 3rd real low
    assert arb.switches[-1][-1] == "acceptance"


def test_slot_arbiter_reset_clears_acceptance_counter():
    """A new request admitted into the slot must not inherit the
    previous request's low-acceptance streak."""
    arb = SlotArbiter(1, _acc_cfg())
    low = np.array([0.0])
    arb.observe(0, **_quiet(1), acceptance=low)
    arb.observe(1, **_quiet(1), acceptance=low)
    arb.reset_slot(0)
    for step in range(2, 4):                     # two lows: still under patience
        assert list(arb.observe(step, **_quiet(1), acceptance=low)) == [0], step
    assert list(arb.observe(4, **_quiet(1), acceptance=low)) == [1]


def test_slot_arbiter_reset_slot_isolates_state():
    arb = SlotArbiter(2, SlotArbiterConfig(n_levels=2, start_idx=0))
    arb.observe(0, nonfinite=np.array([True, False]), amplitude=np.zeros(2))
    assert list(arb.idx) == [1, 0]
    arb.reset_slot(0)                           # new request takes the slot
    assert list(arb.idx) == [0, 0]
    with pytest.raises(ValueError):
        arb.reset_slot(0, start_idx=5)


# ---------------------------------------------------------------------------
# serving engine (device integration)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke("deepseek_7b")
    params = init_params(cfg, jax.random.PRNGKey(3))
    return cfg, params


def _teacher_forced(cfg, params, prompt, n, level="f32"):
    """Greedy reference: re-run prefill on the growing sequence at the
    mode the serving level maps to."""
    mode = dict(SERVE_STEP_LEVELS)[level]
    seq = list(prompt)
    for _ in range(n):
        caches = init_caches(cfg, 1, 64, dtype=jnp.float32)
        logits, _ = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg, mode=mode))(
            params, jnp.asarray([seq], jnp.int32), caches
        )
        seq.append(int(jnp.argmax(logits[0])))
    return seq


def test_continuous_matches_teacher_forcing_under_churn(small_model):
    """More requests than slots, mixed lengths and budgets: every
    request's greedy output must equal its teacher-forced reference —
    admission order, slot reuse and lock-step-free eviction must be
    invisible to each request."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64)
    )
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [4, 5, 6], [9, 8, 7, 6, 5], [2, 2, 2, 2, 2, 2]]
    budgets = [3, 6, 2, 5]
    reqs = [Request(rid=srv.next_rid(), prompt=p, max_new=n)
            for p, n in zip(prompts, budgets)]
    fins = srv.serve(reqs)
    assert srv.stats["prefills"] == 4
    for r, p, n in zip(reqs, prompts, budgets):
        assert fins[r.rid].tokens == _teacher_forced(cfg, params, p, n), r.rid
        assert fins[r.rid].reason == "max_new"


def test_slot_reuse_never_leaks_state(small_model):
    """A request admitted into a RECYCLED slot (after another request
    lived and died there) must produce exactly what it produces in a
    fresh server — KV rows, pos sentinels, SSM state must not leak."""
    cfg, params = small_model
    late = [7, 3, 7, 3, 7]
    # churned server: one slot, three requests through it; 'late' last
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=1, max_len=64)
    )
    churned = srv.generate([[5, 5, 5, 5, 5, 5], [11, 12, 13], late], max_new=5)[-1]
    fresh = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=1, max_len=64)
    ).generate([late], max_new=5)[0]
    assert churned == fresh


@pytest.mark.parametrize("arch", ["deepseek_7b", "jamba_v01_52b"])
def test_mixed_levels_identical_to_alone(arch):
    """THE per-request-precision contract: a batch mixing q16_16 and
    f32 slots gives every request exactly the tokens it gets when
    served alone at its level (row-independent lanes + traced-index
    dispatch; includes the hybrid SSM+attention family)."""
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(4))
    scfg = lambda: ContinuousServerConfig(n_slots=2, max_len=64)
    pa, pb = [1, 2, 3, 4, 5, 6], [9, 8, 7, 6]

    srv = ContinuousBatchingServer(cfg, params, scfg())
    fins = srv.serve([
        Request(rid=0, prompt=pa, max_new=4, level="f32"),
        Request(rid=1, prompt=pb, max_new=4, level="q16_16"),
    ])
    assert srv.stats["level_passes"] == 2 * srv.stats["decode_steps"]  # mixed batch

    alone_a = ContinuousBatchingServer(cfg, params, scfg()).serve(
        [Request(rid=0, prompt=pa, max_new=4, level="f32")])[0]
    alone_b = ContinuousBatchingServer(cfg, params, scfg()).serve(
        [Request(rid=1, prompt=pb, max_new=4, level="q16_16")])[1]
    assert fins[0].tokens == alone_a.tokens
    assert fins[1].tokens == alone_b.tokens
    assert alone_a.tokens != alone_b.tokens  # distinct requests, sanity


def test_masked_lane_cache_magnitude_cannot_perturb_members(small_model):
    """Regression (review finding, confirmed): a non-member lane's LIVE
    cache must not perturb a member's logits.  Before the pristine
    cache view, a masked lane attended to its own cache (q=0 still
    averages the cached V rows), re-acquired nonzero activations, and
    leaked into the FAST path's per-tensor activation exponents — the
    isolation contract silently depended on neighbor magnitudes."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64)
    )
    srv.scheduler.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=8, level="q16_16"))
    for slot, req in srv.scheduler.admit():
        srv._admit(slot, req)

    def plant(node, value):
        """Fill slot 1's cache rows with large live-looking content
        (valid slot positions, huge payloads)."""
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "pos":  # (n_periods, B, L) -> valid positions 0..L-1
                    out[k] = v.at[:, 1].set(jnp.arange(v.shape[2], dtype=v.dtype)[None, :])
                else:
                    out[k] = plant(v, value)
            return out
        return node.at[:, 1].set(jnp.full(node.shape[2:], value, node.dtype))

    mask = jnp.asarray(np.array([True, False]))
    li = jnp.int32(srv.level_names.index("q16_16"))

    def run(pool):
        logits, _ = srv._pool_pass(
            li, srv.params, srv._tok[:, None], srv._pos, pool, mask,
            srv._zero_logits,
        )
        return np.asarray(logits[0])

    base = jax.tree.map(jnp.copy, srv.pool)
    l_clean = run(jax.tree.map(jnp.copy, base))
    l_dirty = run(plant(jax.tree.map(jnp.copy, base), 5000.0))
    np.testing.assert_array_equal(l_clean, l_dirty)


def test_unknown_level_rejected_before_slot_binding(small_model):
    """Regression (review finding): an invalid Request.level must fail
    at submission — before a slot is bound — and leave the server fully
    usable (no zombie slot entries, no stranded predecessors)."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64)
    )
    good = Request(rid=0, prompt=[1, 2, 3], max_new=2)
    bad = Request(rid=1, prompt=[4, 5], max_new=2, level="q8_8")  # not a serve level
    with pytest.raises(ValueError, match="unknown level"):
        srv.serve([good, bad])
    assert not srv.scheduler.has_work()          # nothing stranded
    with pytest.raises(ValueError, match="duplicate request id"):
        srv.serve([good, Request(rid=0, prompt=[9], max_new=1)])
    outs = srv.generate([[1, 2, 3]], max_new=2)  # server still healthy
    assert len(outs[0]) == 5


def test_server_lifetime_state_is_bounded(small_model):
    """serve() hands results out and drops them from the scheduler — a
    long-lived server must not accumulate per-request state forever."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64)
    )
    for _ in range(3):
        srv.generate([[1, 2, 3], [4, 5]], max_new=2)
    assert srv.scheduler.finished == {}
    assert srv.scheduler._submitted == set()


def test_arbiter_escalates_slot_mid_request(small_model):
    """Per-request precision is ADAPTIVE: with an impossible amplitude
    threshold every health sync escalates the slot one rung, so a
    q16_16 request finishes at f32 — switched via the traced index
    with zero retraces (the same compiled tick serves both levels)."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params,
        ContinuousServerConfig(
            n_slots=1, max_len=64, health_sync_every=2,
            default_level="q16_16",
            arbiter=SlotArbiterConfig(
                n_levels=len(SERVE_STEP_LEVELS), amp_threshold=-1.0,
                cooldown_steps=1, stable_steps=10**6,
            ),
        ),
    )
    fins = srv.serve([Request(rid=0, prompt=[1, 2, 3, 4], max_new=10)])
    assert fins[0].n_generated == 10
    assert srv.arbiter.idx[0] == len(SERVE_STEP_LEVELS) - 1   # escalated to top
    assert any(reason == "amplitude" for *_, reason in srv.arbiter.switches)
    # both levels ran within one request's decode
    assert srv.stats["level_passes"] == srv.stats["decode_steps"]


def test_eos_mode_budgets_and_eviction(small_model):
    """EOS mode (per-step token pull): unlikely EOS id -> budgets still
    bound every request; an EOS id that CAN be sampled terminates early
    with reason 'eos' and the slot is refilled."""
    cfg, params = small_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64, eos_id=127)
    )
    reqs = [Request(rid=srv.next_rid(), prompt=[1, 2, 3], max_new=4),
            Request(rid=srv.next_rid(), prompt=[7, 7], max_new=3)]
    fins = srv.serve(reqs)
    for r in reqs:
        f = fins[r.rid]
        assert f.reason in ("eos", "max_new")
        assert f.n_generated <= r.max_new
        if f.reason == "eos":
            assert f.tokens[-1] == 127
