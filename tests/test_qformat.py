"""C1 validation: Q-format arithmetic vs NumPy-int64 / Python-int oracles,
and the paper's stated error bounds (§3.1, Eq. 6)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _pbt import given, strategies as st

from repro.core import qformat as qf

I32 = st.integers(min_value=-(2**31), max_value=2**31 - 1)


def as_i32(x):
    return jnp.asarray(np.int32(x))


# ---------------------------------------------------------------------------
# widening multiply: the paired-u32-limb 64-bit product is bit-exact
# ---------------------------------------------------------------------------


@given(I32, I32)
def test_widening_mul_exact(a, b):
    hi, lo = qf.widening_mul_i32(as_i32(a), as_i32(b))
    got = (int(hi) << 32) | int(lo)
    want = (a * b) & ((1 << 64) - 1)  # two's complement bits
    assert got == want


@given(I32, I32)
def test_qmul_floor_matches_c_semantics(a, b):
    """rounding=False reproduces Listing 1 exactly: ((int64)a*b) >> 16."""
    got = int(qf.q_mul(as_i32(a), as_i32(b), rounding=False))
    want = (a * b) >> 16  # python ints: arithmetic shift, infinite precision
    want = ((want + 2**31) % 2**32) - 2**31  # truncate to int32 (C cast)
    assert got == want


@given(I32, I32)
def test_qmul_sat_matches_listing(a, b):
    """mulQ_sat: clamp the shifted 64-bit value to int32 range."""
    got = int(qf.q_mul(as_i32(a), as_i32(b), rounding=False, saturate=True))
    want = (a * b) >> 16
    want = max(min(want, 2**31 - 1), -(2**31))
    assert got == want


@given(I32, I32)
def test_qmul_rounding_matches_round_half_up(a, b):
    got = int(qf.q_mul(as_i32(a), as_i32(b), rounding=True, saturate=True))
    want = (a * b + (1 << 15)) >> 16
    want = max(min(want, 2**31 - 1), -(2**31))
    assert got == want


# ---------------------------------------------------------------------------
# paper Eq. 6: |eps_mul| <= 2**-17 (round-to-nearest), < 2**-16 (floor)
# ---------------------------------------------------------------------------


FLOATS = st.floats(min_value=-100.0, max_value=100.0, allow_nan=False, width=32)


@given(FLOATS, FLOATS)
def test_mul_error_bound_paper_eq6(x, y):
    xq = qf.to_fixed(x)
    yq = qf.to_fixed(y)
    # exact real values of the quantized inputs (float64 via python ints)
    xr = int(xq) / 65536.0
    yr = int(yq) / 65536.0
    zq = qf.q_mul(xq, yq, rounding=True)
    err = abs(int(zq) / 65536.0 - xr * yr)
    assert err <= 2.0**-17 + 1e-12, f"paper Eq.6 violated: {err}"


@given(FLOATS, FLOATS)
def test_mul_error_bound_floor(x, y):
    xq, yq = qf.to_fixed(x), qf.to_fixed(y)
    xr, yr = int(xq) / 65536.0, int(yq) / 65536.0
    zq = qf.q_mul(xq, yq, rounding=False)
    err = abs(int(zq) / 65536.0 - xr * yr)
    assert err < 2.0**-16 + 1e-12


# ---------------------------------------------------------------------------
# add/sub exactness (paper Eq. 3) and saturating boundary (paper §3.1.2)
# ---------------------------------------------------------------------------


@given(I32, I32)
def test_add_sat(a, b):
    got = int(qf.q_add_sat(as_i32(a), as_i32(b)))
    want = max(min(a + b, 2**31 - 1), -(2**31))
    assert got == want


@given(I32, I32)
def test_sub_sat(a, b):
    got = int(qf.q_sub_sat(as_i32(a), as_i32(b)))
    want = max(min(a - b, 2**31 - 1), -(2**31))
    assert got == want


@given(st.floats(-16000, 16000, allow_nan=False), st.floats(-16000, 16000, allow_nan=False))
def test_add_exact_when_in_range(x, y):
    """Paper Eq. 3: addition is algebraically exact absent overflow —
    the raw integer sum IS the Q sum (scaling factor preserved)."""
    xq, yq = qf.to_fixed(x), qf.to_fixed(y)
    zq = qf.q_add(xq, yq)
    assert int(zq) == int(xq) + int(yq)


# ---------------------------------------------------------------------------
# conversion round-trips and range (paper Eq. 1-2)
# ---------------------------------------------------------------------------


@given(st.floats(min_value=-32768.0, max_value=32767.5, allow_nan=False, width=32))
def test_roundtrip_within_resolution(x):
    xq = qf.to_fixed(x)
    # float32 inputs: x*65536 is exact (scaling by a power of two), so
    # the only error is the round-to-nearest-integer: <= 0.5 ulp.
    assert abs(int(xq) / 65536.0 - float(x)) <= qf.Q16_16.resolution / 2 + 1e-12


def test_range_constants():
    assert qf.Q16_16.min_value == -32768.0
    assert qf.Q16_16.max_value == pytest.approx(32767.9999847, abs=1e-6)
    assert qf.Q16_16.resolution == pytest.approx(1.52587890625e-5)


def test_saturating_conversion_boundaries():
    assert int(qf.to_fixed(1e9)) == 2**31 - 1
    assert int(qf.to_fixed(-1e9)) == -(2**31)
    assert int(qf.to_fixed(0.0)) == 0


def test_vectorized_ops_shapes(rng):
    a = qf.to_fixed(rng.uniform(-10, 10, size=(64, 32)).astype(np.float32))
    b = qf.to_fixed(rng.uniform(-10, 10, size=(64, 32)).astype(np.float32))
    assert qf.q_mul(a, b).shape == (64, 32)
    assert qf.q_add_sat(a, b).dtype == jnp.int32


# ---------------------------------------------------------------------------
# paper §4.3.2: the 88-byte static footprint decomposition
# ---------------------------------------------------------------------------


def test_static_footprint_matches_paper():
    fp = qf.static_footprint_bytes(num_ops=6, cordic_iters=16)
    assert fp["dispatch_table_bytes"] == 24
    assert fp["cordic_table_bytes"] == 64
    assert fp["total_bytes"] == 88
