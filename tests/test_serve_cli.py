"""Launcher CLI: every serving flag must round-trip through
``serving_config_from_args`` into a validated :class:`ServingConfig`.

The launcher is the one place flag spellings meet config fields; a
typo'd ``dest`` or a forgotten field silently serves with defaults, so
this suite pins the mapping flag-by-flag, plus one end-to-end ``main``
run that writes the telemetry artifacts (--metrics-out / --trace-out)
to disk.
"""

import json

import pytest

from repro.launch.serve import MAX_LEN, build_parser, serving_config_from_args


def _cfg(argv):
    return serving_config_from_args(
        build_parser().parse_args(["--arch", "gemma2_2b"] + argv))


def test_defaults_round_trip():
    scfg = _cfg(["--continuous"])
    assert scfg.n_slots == 2
    assert scfg.max_len == MAX_LEN
    assert scfg.cache == "contiguous"
    assert scfg.speculative is None
    assert scfg.telemetry.enabled is False
    assert scfg.telemetry.trace is False    # no --trace-out given


def test_paged_flags_round_trip():
    scfg = _cfg(["--continuous", "--paged", "--page-size", "8",
                 "--prefill-chunk", "8", "--prefix-sharing",
                 "--n-pages", "40", "--slots", "3"])
    assert scfg.cache == "paged"
    assert scfg.page_size == 8
    assert scfg.prefill_chunk == 8   # prefix sharing pins chunk == page
    assert scfg.prefix_sharing is True
    assert scfg.n_pages == 40
    assert scfg.n_slots == 3


def test_speculative_flags_round_trip():
    scfg = _cfg(["--continuous", "--speculative", "--spec-k", "4",
                 "--draft-level", "q8_8"])
    assert scfg.speculative is not None
    assert scfg.speculative.k == 4
    assert scfg.speculative.draft_level == "q8_8"
    assert scfg.speculative.max_len == MAX_LEN


@pytest.mark.parametrize("argv,enabled,trace", [
    ([], False, True),
    (["--metrics-out", "m.prom"], True, False),
    (["--trace-out", "t.json"], True, True),
    (["--metrics-out", "m.prom", "--trace-out", "t.json"], True, True),
])
def test_telemetry_enabled_iff_output_requested(argv, enabled, trace):
    scfg = _cfg(["--continuous"] + argv)
    assert scfg.telemetry.enabled is enabled
    if enabled:
        assert scfg.telemetry.trace is trace


def test_invalid_flag_combination_raises():
    # page_size must divide into max_len; the config's own validation
    # fires through the CLI path, not just direct construction
    with pytest.raises(ValueError):
        _cfg(["--continuous", "--paged", "--page-size", "1000"])


def test_main_end_to_end_writes_artifacts(tmp_path, capsys):
    from repro.launch.serve import main

    metrics = tmp_path / "metrics.prom"
    trace = tmp_path / "trace.json"
    # page size 4: it must divide gemma2's 8-row sliding window too
    main(["--arch", "gemma2_2b", "--continuous", "--paged",
          "--page-size", "4", "--max-new", "2",
          "--metrics-out", str(metrics), "--trace-out", str(trace)])

    out = capsys.readouterr().out
    assert "req" in out and "stats:" in out

    text = metrics.read_text()
    assert "# TYPE decode_ticks_total counter" in text
    assert "prefills_total 4" in text    # the launcher serves 4 prompts

    tr = json.loads(trace.read_text())
    names = {e["name"] for e in tr["traceEvents"]}
    assert "decode-tick" in names and "admit" in names
