"""Dry-run machinery tests: HLO analyzer unit tests + an end-to-end
mini dry-run in a subprocess (own XLA device-count override, so the
main test process keeps its single real device)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,2]{1,0}") == 8
    assert _shape_bytes("(f32[8], s8[16])") == 32 + 16
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("token[]") == 0


HLO_SAMPLE = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
      %p = (s32[], f32[64,64]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[64,64] get-tuple-element(%p), index=1
      %w = f32[64,64] constant({...})
      %dot.1 = f32[64,64] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64] all-reduce(%dot.1), replica_groups={}, to_apply=%add.1
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar)
    }

    %cond.1 (p2: (s32[], f32[64,64])) -> pred[] {
      %p2 = (s32[], f32[64,64]) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i3, %n), direction=LT
    }

    ENTRY %main (a: f32[64,64]) -> f32[64,64] {
      %a = f32[64,64] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[64,64]) tuple(%zero, %a)
      %wl = (s32[], f32[64,64]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[64,64] get-tuple-element(%wl), index=1
    }
    """)


def test_trip_count_multiplication():
    c = analyze_hlo(HLO_SAMPLE)
    # dot: 2 * 64*64 * 64 flops, x10 trips
    assert c.flops == pytest.approx(2 * 64 * 64 * 64 * 10)
    assert c.collective_bytes["all-reduce"] == pytest.approx(64 * 64 * 4 * 10)
    assert c.collective_counts["all-reduce"] == 10


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax
from jax.sharding import Mesh
from repro.launch.steps import build_cell
from repro.launch import dryrun
import numpy as np

mesh = jax.make_mesh((2, 4), ("data", "model"))
dryrun.make_mesh_by_name = lambda name: mesh  # shrink to the host's 8 devices
rec = dryrun.run_cell("{arch}", "{shape}", "host8", verbose=False)
print("RESULT:" + json.dumps({{"status": rec["status"],
    "collective": rec.get("hlo_costs", {{}}).get("total_collective_bytes", 0),
    "flops": rec.get("hlo_costs", {{}}).get("flops", 0)}}))
"""


@pytest.mark.parametrize("arch,shape", [("gemma2_2b", "train_4k"), ("mamba2_1_3b", "decode_32k")])
def test_mini_dryrun_subprocess(arch, shape):
    """Full dry-run path on an 8-device host mesh in a subprocess."""
    code = DRYRUN_SNIPPET.format(arch=arch, shape=shape)
    env = dict(PYTHONPATH="src")
    import os

    env.update(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).parent.parent, timeout=560, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    rec = json.loads(line[len("RESULT:"):])
    assert rec["status"] == "ok"
    assert rec["flops"] > 0
    assert rec["collective"] > 0


def test_skip_rules():
    """long_500k skip/run set matches DESIGN.md §4 exactly."""
    from repro.configs import ARCH_IDS, get_config

    runs = {a for a in ARCH_IDS if get_config(a).is_subquadratic}
    assert runs == {"mixtral_8x22b", "jamba_v01_52b", "mamba2_1_3b"}


def test_production_mesh_shapes():
    """Mesh factory contract (without touching device state: just specs)."""
    from repro.launch.steps import SHAPES

    assert SHAPES["train_4k"].batch == 256 and SHAPES["train_4k"].seq == 4096
    assert SHAPES["prefill_32k"].batch == 32 and SHAPES["prefill_32k"].seq == 32768
    assert SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].batch == 1 and SHAPES["long_500k"].seq == 524288
