"""Universal CORDIC (Walther modes) validation: schedule/gain constants,
per-op error bounds vs float64 oracles over each op's full input range,
bit-determinism, and FAST/PRECISE dispatch through MathEngine.

The asserted bounds are the ones documented in ``core/cordic.py``'s
module docstring (Eq. 14 analogues); each was measured with >= 2x
margin over a 12k-point sweep.
"""

import math

import numpy as np
import pytest
from _pbt import given, strategies as st

from repro.core import cordic as cd
from repro.core.precision import MathEngine, Mode
from repro.core.qformat import Q16_16, from_fixed, to_fixed

ONE = 1 << 16


def q(x):
    return np.round(np.asarray(x, np.float64) * ONE).astype(np.int32)


def f(v):
    return np.asarray(v, np.int64) / ONE


# ---------------------------------------------------------------------------
# schedule and gain constants (Walther 1971)
# ---------------------------------------------------------------------------


def test_hyperbolic_schedule_repeats():
    # repeats at 4 and 13 (r_{j+1} = 3 r_j + 1), nowhere else in 20 stages
    sched = cd.hyperbolic_schedule(20)
    assert sched == (1, 2, 3, 4, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 13, 14, 15, 16, 17, 18)
    # convergence domain with repeats exceeds ln2/2 and atanh(3/5)
    assert sum(math.atanh(2.0 ** -i) for i in sched) == pytest.approx(1.1182, abs=1e-3)


def test_hyperbolic_gain_constant():
    # K_h -> 0.8281593... ; table stores round(K_h^-1 * 2^29)
    k_inv = cd.hyper_gain_inverse(cd.hyperbolic_schedule(20), 30) / (1 << 30)
    assert k_inv == pytest.approx(1.2074971, abs=1e-6)
    assert 1.0 / k_inv == pytest.approx(0.8281594, abs=1e-6)


def test_atanh_table_head():
    tab = cd.atanh_table(cd.hyperbolic_schedule(4), 16)
    want = [round(math.atanh(2.0 ** -i) * ONE) for i in (1, 2, 3, 4)]
    assert list(tab) == want


def test_ln2_constants():
    assert cd.LN2_Q16 == 45426
    assert cd.EXP_SAT_HI_Q16 == round(math.log(32768.0) * ONE)


# ---------------------------------------------------------------------------
# error bounds vs float64 oracles (documented Eq. 14 analogues)
# ---------------------------------------------------------------------------


def test_atan2_dense_grid_bound(rng):
    y = rng.uniform(-200.0, 200.0, 4001)
    x = rng.uniform(-200.0, 200.0, 4001)
    got = f(cd.atan2_q16(q(y), q(x)))
    want = np.arctan2(f(q(y)), f(q(x)))
    assert np.max(np.abs(got - want)) <= 1e-4


def test_atan2_axes_and_quadrants():
    pts = [(0.0, 1.0), (1.0, 0.0), (0.0, -1.0), (-1.0, 0.0),
           (1.0, 1.0), (1.0, -1.0), (-1.0, -1.0), (-1.0, 1.0),
           (1e-4, -100.0), (-1e-4, -100.0)]
    for y, x in pts:
        got = float(f(cd.atan2_q16(q(y), q(x))))
        assert got == pytest.approx(math.atan2(y, x), abs=1e-4), (y, x)
    assert int(cd.atan2_q16(np.int32(0), np.int32(0))) == 0


def test_sqrt_bound(rng):
    w = np.concatenate([
        rng.uniform(2.0 ** -16, 1.0, 3000),
        rng.uniform(1.0, 100.0, 3000),
        rng.uniform(100.0, 32767.0, 3000),
    ])
    wq = np.maximum(q(w), 1)
    got = f(cd.sqrt_q16(wq))
    want = np.sqrt(f(wq))
    assert np.all(np.abs(got - want) <= 2.0 ** -16 + 3e-5 * want)
    # domain edges
    assert int(cd.sqrt_q16(np.int32(0))) == 0
    assert int(cd.sqrt_q16(np.int32(-123))) == 0
    assert f(cd.sqrt_q16(np.int32((1 << 31) - 1))) == pytest.approx(math.sqrt(32768.0), rel=1e-4)


def test_exp_bound(rng):
    t = rng.uniform(-11.5, 10.39, 9000)
    tq = q(t)
    tq = tq[tq < cd.EXP_SAT_HI_Q16]
    got = f(cd.exp_q16(tq))
    want = np.exp(f(tq))
    assert np.all(np.abs(got - want) <= 2.0 ** -16 + 6e-5 * want)
    # saturation and flush-to-zero edges
    assert int(cd.exp_q16(np.int32(cd.EXP_SAT_HI_Q16))) == (1 << 31) - 1
    assert int(cd.exp_q16(np.int32(20 * ONE))) == (1 << 31) - 1
    assert int(cd.exp_q16(np.int32(cd.EXP_FLUSH_LO_Q16))) == 0
    assert float(f(cd.exp_q16(np.int32(0)))) == pytest.approx(1.0, abs=2e-5)


def test_log_bound(rng):
    w = np.concatenate([
        rng.uniform(2.0 ** -10, 1.0, 3000),
        rng.uniform(1.0, 32767.0, 3000),
    ])
    wq = np.maximum(q(w), 1)
    got = f(cd.log_q16(wq))
    want = np.log(f(wq))
    assert np.max(np.abs(got - want)) <= 8e-5
    # log(w <= 0) pins to Q16.16 min (the -inf stand-in)
    assert int(cd.log_q16(np.int32(0))) == -(1 << 31)
    assert int(cd.log_q16(np.int32(-5))) == -(1 << 31)


def test_exp_log_roundtrip(rng):
    t = rng.uniform(-8.0, 8.0, 2000)
    back = f(cd.log_q16(cd.exp_q16(q(t))))
    # log inherits exp's output quantization as relative error: a small
    # e^t has few significant Q16.16 bits, so the bound carries a
    # 2^-16 * e^-t term on top of the two ops' intrinsic bounds.
    bound = 2e-4 + 1.5 * 2.0 ** -16 * np.exp(-f(q(t)))
    assert np.all(np.abs(back - f(q(t))) <= bound)


def test_tanh_bound(rng):
    t = rng.uniform(-16.0, 16.0, 9000)
    got = f(cd.tanh_q16(q(t)))
    want = np.tanh(f(q(t)))
    assert np.max(np.abs(got - want)) <= 6e-5
    assert np.all(np.abs(got) <= 1.0)  # never overshoots saturation


def test_sigmoid_bound(rng):
    t = rng.uniform(-20.0, 20.0, 9000)
    got = f(cd.sigmoid_q16(q(t)))
    want = 1.0 / (1.0 + np.exp(-f(q(t))))
    assert np.max(np.abs(got - want)) <= 5e-5
    assert np.all((got >= 0.0) & (got <= 1.0))


@given(st.floats(min_value=-100.0, max_value=100.0, allow_nan=False),
       st.floats(min_value=-100.0, max_value=100.0, allow_nan=False))
def test_atan2_property(y, x):
    got = float(f(cd.atan2_q16(q(y), q(x))))
    want = math.atan2(float(q(y)) / ONE, float(q(x)) / ONE)
    assert got == pytest.approx(want, abs=1e-4)


@given(st.floats(min_value=-6.0, max_value=6.0, allow_nan=False))
def test_tanh_odd_symmetry(t):
    a = int(cd.tanh_q16(q(t)))
    b = int(cd.tanh_q16(q(-t)))
    # odd symmetry up to the 1-ulp floor-rounding asymmetry
    assert abs(a + b) <= 2


def test_determinism_bitwise(rng):
    t = q(rng.uniform(-20, 20, 1024))
    for op in (cd.sqrt_q16, cd.exp_q16, cd.log_q16, cd.tanh_q16, cd.sigmoid_q16):
        assert np.array_equal(np.asarray(op(t)), np.asarray(op(t)))
    y, x = q(rng.uniform(-5, 5, 257)), q(rng.uniform(-5, 5, 257))
    assert np.array_equal(np.asarray(cd.atan2_q16(y, x)), np.asarray(cd.atan2_q16(y, x)))


# ---------------------------------------------------------------------------
# MathEngine dispatch: both modes, same call sites (R1)
# ---------------------------------------------------------------------------


def test_opset_contains_universal_family():
    from repro.core.precision import OP_SET

    for op in ("atan2", "sqrt", "exp", "log", "tanh", "sigmoid"):
        assert op in OP_SET


@pytest.mark.parametrize(
    "op,args,tol",
    [
        ("atan2", (np.float32(0.7), np.float32(-1.3)), 1e-4),
        ("sqrt", (np.float32(17.0),), 1e-4),
        ("exp", (np.float32(2.5),), 1e-3),
        ("log", (np.float32(7.25),), 1e-4),
        ("tanh", (np.float32(-0.8),), 1e-4),
        ("sigmoid", (np.float32(1.9),), 1e-4),
    ],
)
def test_engine_dispatch_fast_matches_precise(op, args, tol):
    eng = MathEngine(Mode.PRECISE)
    precise = float(eng.call(op, *args))
    eng.set_mode(Mode.FAST)
    fast = float(eng.call(op, *args))
    assert fast == pytest.approx(precise, abs=tol)


def test_engine_fast_path_is_cordic():
    """The FAST table must hold the CORDIC kernels, not jnp fallbacks:
    raw results agree bitwise with the Q16.16 op."""
    eng = MathEngine(Mode.FAST)
    x = np.float32(3.7)
    got = np.asarray(eng.call("sqrt", x))
    want = np.asarray(from_fixed(cd.sqrt_q16(to_fixed(x, Q16_16)), Q16_16))
    assert np.array_equal(got, want)
