"""Substrate integration tests: data determinism, checkpoint
atomicity/restart, trainer e2e (loss decreases, failure injection,
arbiter-driven precision switching), batched serving consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import smoke
from repro.core.precision import Mode
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import decode_step, init_caches, init_params, prefill_step, train_loss
from repro.runtime.serve import BatchedServer, ServerConfig
from repro.runtime.train_loop import InjectedFailure, Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_in_step():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=8)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"], d1.batch(8)["tokens"])


def test_data_host_sharding_partitions():
    full = SyntheticLM(DataConfig(vocab=50, seq_len=16, global_batch=8)).batch(3)
    parts = [
        SyntheticLM(DataConfig(vocab=50, seq_len=16, global_batch=8, num_hosts=4, host_id=h)).batch(3)
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate([p["tokens"] for p in parts]), full["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.int32)}}
    ck.save(10, tree, blocking=True)
    out = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert ck.latest_step() == 10


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"x": jnp.zeros((2,))}
    ck.save(1, tree, blocking=True)
    # a crashed half-save must be invisible
    (tmp_path / "step_2.tmp").mkdir()
    (tmp_path / "step_5").mkdir()  # committed dir without manifest = corrupt
    assert ck.latest_step() == 1


def test_checkpoint_gc_keeps_newest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.all_steps() == [3, 4]


# ---------------------------------------------------------------------------
# trainer e2e
# ---------------------------------------------------------------------------


def _trainer(tmp_path, **kw):
    cfg = smoke("deepseek_7b")
    defaults = dict(total_steps=16, ckpt_every=8, ckpt_dir=str(tmp_path), log_every=100)
    defaults.update(kw)
    return Trainer(cfg, TrainerConfig(**defaults))


def test_train_loss_decreases(tmp_path):
    out = _trainer(tmp_path, total_steps=30).run()
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first - 0.1, (first, last)


def test_failure_injection_and_bitwise_resume(tmp_path):
    with pytest.raises(InjectedFailure):
        _trainer(tmp_path, total_steps=16, ckpt_every=4, crash_at_step=10).run()
    # restart picks up from the last committed checkpoint (step 7)
    t2 = _trainer(tmp_path, total_steps=16, ckpt_every=4)
    assert t2.start_step == 8
    out2 = t2.run()

    # reference: uninterrupted run with identical config/seed
    ref = _trainer(str(tmp_path) + "_ref", total_steps=16, ckpt_every=4).run()
    resumed = {h["step"]: h["loss"] for h in out2["history"]}
    reference = {h["step"]: h["loss"] for h in ref["history"]}
    for s in range(10, 16):
        assert resumed[s] == pytest.approx(reference[s], rel=1e-5), s


def test_arbiter_switches_on_injected_nan(tmp_path):
    t = _trainer(tmp_path, total_steps=12, use_arbiter=True, start_mode=Mode.FAST)
    # sabotage: force a NaN loss observation mid-run via arbiter API
    t.arbiter.observe(0, float("nan"), 1.0)
    assert t.arbiter.mode is Mode.PRECISE
    out = t.run()
    assert out["history"][-1]["mode"] in ("fast", "precise")


def test_trainer_mode_switch_preserves_training(tmp_path):
    t = _trainer(tmp_path, total_steps=20, start_mode=Mode.PRECISE)
    # manual mid-run switch: run 10 steps, switch, run 10 more
    t.tcfg.total_steps = 10
    t.run()
    latency_us = t.engine.set_mode(Mode.FAST)
    assert latency_us >= 0
    t.tcfg.total_steps = 20
    t.start_step = 10
    out = t.run()
    modes = {h["mode"] for h in out["history"]}
    assert "fast" in modes
    assert np.isfinite(out["final_loss"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_serving_matches_teacher_forcing():
    """Greedy decode through the cache must equal argmax of the full
    forward at each position (prefill/decode correctness).  The
    reference runs mode="exact" — the serving mode the server's f32
    level maps to (SERVE_STEP_LEVELS)."""
    cfg = smoke("deepseek_7b")
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompt = list(range(1, 9))
    srv = BatchedServer(cfg, params, ServerConfig(max_batch=1, max_len=64, max_new=6))
    out = srv.generate([prompt])[0]

    # teacher-forced reference: repeatedly run prefill on the growing
    # sequence (no cache reuse) and take argmax
    seq = list(prompt)
    for _ in range(6):
        caches = init_caches(cfg, 1, 64)
        logits, _ = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg, mode="exact"))(
            params, jnp.asarray([seq], jnp.int32), caches
        )
        seq.append(int(jnp.argmax(logits[0])))
    assert out == seq, (out, seq)


@pytest.mark.parametrize(
    "arch",
    [
        "gemma2_2b",
        "mixtral_8x22b",
        "mamba2_1_3b",
        # jamba un-xfailed: the hybrid divergence was bf16 rounding of
        # an O(1e3) residual stream amplifying shape-dependent gemm
        # noise (one bf16 ulp = 8 at that magnitude); serving now runs
        # the f32 "exact" mode + f32 caches, so decode agrees with
        # prefill re-derivation across all families.
        "jamba_v01_52b",
        "minicpm3_4b",
    ],
)
def test_serving_decode_consistency_all_families(arch):
    """Same check across attention variants (SWA rolling cache,
    local-global, MoE, SSD recurrence, hybrid, MLA absorbed decode)."""
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(4))
    prompt = list(range(2, 12))
    srv = BatchedServer(cfg, params, ServerConfig(max_batch=1, max_len=64, max_new=4))
    out = srv.generate([prompt])[0]

    seq = list(prompt)
    for _ in range(4):
        caches = init_caches(cfg, 1, 64)
        logits, _ = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg, mode="exact"))(
            params, jnp.asarray([seq], jnp.int32), caches
        )
        seq.append(int(jnp.argmax(logits[0])))
    assert out == seq, (arch, out, seq)


def test_server_mode_switch_o1():
    cfg = smoke("deepseek_7b")
    params = init_params(cfg, jax.random.PRNGKey(5))
    srv = BatchedServer(cfg, params, ServerConfig(max_batch=2, max_len=32, max_new=2))
    srv.generate([[1, 2, 3], [4, 5, 6, 7]])  # warm precise
    srv.set_mode(Mode.FAST)
    out = srv.generate([[1, 2, 3], [4, 5, 6, 7]])  # compiles fast path once
    srv.set_mode(Mode.PRECISE)
    lat = srv.set_mode(Mode.FAST)  # now both warm: O(1)
    assert lat < 5e4, lat
    assert len(out) == 2 and all(len(o) > 3 for o in out)
