"""Fused FAST-path SwiGLU (kernels/fused_mlp) + QuantizedWeightCache.

Covers the PR-3 acceptance contract:

* the Pallas kernel matches the NumPy-int64 oracle on the shared body
  (integer intermediates bit-exact, float epilogue at f32 rounding);
* the fused path tracks the unfused ``dot_fast_int8`` + ``psilu``
  composition and the f32 reference within quantization tolerance;
* ``dot_fast_int8`` with a pre-quantized weight operand is bit-exact
  vs. the per-call-quantization path, and still differentiable (STE);
* QuantizedWeightCache: quantize-once counting, coherence across
  ``set_level`` / ``engine.at``, barrier-mediated invalidation;
* the decode step with attached weights performs ZERO weight
  quantizations (counting hook on ``quantize_pow2``);
* vectorized server sampling: greedy unchanged, EOS trimming,
  temperature path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import quantization
from repro.core.quantization import QuantizedWeightCache, quantize_pow2
from repro.kernels.fused_mlp.fused_mlp import fused_swiglu_kernel_call
from repro.kernels.fused_mlp.ops import fused_swiglu, fused_swiglu_parts, fused_swiglu_xla
from repro.kernels.fused_mlp.ref import fused_swiglu_ref
from repro.models.layers import (
    attach_quantized_weights,
    dot_fast_int8,
    psilu,
    swiglu_mlp,
)


def rand_int8(rng, shape):
    return rng.integers(-127, 128, size=shape, dtype=np.int8)


SHAPES = [
    (8, 128, 128),
    (16, 256, 384),
    (100, 200, 300),    # non-multiples: exercises padding
    (1, 128, 128),
    (257, 129, 511),    # awkward primes
]


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_kernel_matches_oracle(rng, shape):
    M, K, F = shape
    x = rand_int8(rng, (M, K))
    wg = rand_int8(rng, (K, F))
    wu = rand_int8(rng, (K, F))
    ea = np.int32(-9)
    eg = rng.integers(-12, -5, size=(F,), dtype=np.int32)
    eu = rng.integers(-12, -5, size=(F,), dtype=np.int32)

    got = np.asarray(
        fused_swiglu_kernel_call(x, wg, wu, ea, eg, eu, bm=128, bn=128, bk=128)
    )
    want, gate_ref, sig_ref = fused_swiglu_ref(x, wg, wu, ea, eg, eu, return_parts=True)

    # shared-body integer contract: BIT-exact (XLA form == kernel == oracle)
    out_x, gate_x, sig_x = (np.asarray(v) for v in fused_swiglu_parts(x, wg, wu, ea, eg, eu))
    np.testing.assert_array_equal(gate_x, gate_ref)
    np.testing.assert_array_equal(sig_x, sig_ref)
    np.testing.assert_array_equal(out_x, got)  # kernel == XLA form, bitwise

    # float epilogue: one f32 rounding event vs the float64 oracle
    scale = np.abs(want).max()
    np.testing.assert_allclose(got, want, atol=3e-6 * max(scale, 1.0), rtol=3e-6)


def test_fused_kernel_block_sweep(rng):
    M, K, F = 300, 700, 260
    x = rand_int8(rng, (M, K))
    wg = rand_int8(rng, (K, F))
    wu = rand_int8(rng, (K, F))
    ea = np.int32(-8)
    eg = np.full((F,), -9, np.int32)
    eu = np.full((F,), -10, np.int32)
    want = fused_swiglu_ref(x, wg, wu, ea, eg, eu)
    for bm, bn, bk in [(128, 128, 128), (256, 128, 256), (512, 512, 512)]:
        got = np.asarray(
            fused_swiglu_kernel_call(x, wg, wu, ea, eg, eu, bm=bm, bn=bn, bk=bk)
        )
        np.testing.assert_allclose(got, want, rtol=3e-6, atol=3e-6 * np.abs(want).max())


def test_fused_float_boundary_vs_unfused_composition(rng):
    """silu(x@Wg) * (x@Wu): fused single-correction path vs the
    three-dispatch composition vs the f32 reference."""
    M, K, F = 32, 256, 192
    x = rng.uniform(-1, 1, (M, K)).astype(np.float32)
    wg = (rng.uniform(-1, 1, (K, F)) * 0.1).astype(np.float32)
    wu = (rng.uniform(-1, 1, (K, F)) * 0.1).astype(np.float32)

    fused = np.asarray(fused_swiglu(jnp.asarray(x), jnp.asarray(wg), jnp.asarray(wu)))

    gate = dot_fast_int8(jnp.asarray(x), jnp.asarray(wg))
    up = dot_fast_int8(jnp.asarray(x), jnp.asarray(wu))
    unfused = np.asarray(psilu(gate.astype(jnp.float32), "fast") * up)

    ref = jax.nn.silu(x.astype(np.float64) @ wg) * (x.astype(np.float64) @ wu)
    ref = np.asarray(ref)
    scale = np.abs(ref).max()

    err_fused = np.abs(fused - ref).max()
    err_unfused = np.abs(unfused - ref).max()
    # both sit on the same int8 quantization grid; the fused path must
    # not be worse than ~the composition (it removes rounding events)
    assert err_fused < 0.05 * scale + 1e-3, (err_fused, scale)
    assert err_fused < 2.0 * err_unfused + 1e-4, (err_fused, err_unfused)


# ---------------------------------------------------------------------------
# dot_fast_int8 with pre-quantized weights (XLA FAST path satellite)
# ---------------------------------------------------------------------------


def test_dot_fast_cached_bit_exact(rng):
    x = rng.uniform(-2, 2, (16, 96)).astype(np.float32)
    w = rng.uniform(-1, 1, (96, 64)).astype(np.float32)
    wq = quantize_pow2(w, bits=8, axis=1)
    base = np.asarray(dot_fast_int8(jnp.asarray(x), jnp.asarray(w)))
    cached = np.asarray(dot_fast_int8(jnp.asarray(x), jnp.asarray(w), wq=wq))
    as_dict = np.asarray(
        dot_fast_int8(jnp.asarray(x), jnp.asarray(w), wq={"q": wq.q, "exp": wq.exp})
    )
    np.testing.assert_array_equal(base, cached)
    np.testing.assert_array_equal(base, as_dict)


def test_dot_fast_cached_gradient(rng):
    """The cached forward keeps the STE backward of the uncached path."""
    x = rng.uniform(-1, 1, (8, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    wq = quantize_pow2(w, bits=8, axis=1)

    def loss_cached(x, w):
        return jnp.sum(dot_fast_int8(x, w, wq=wq) ** 2)

    def loss_plain(x, w):
        return jnp.sum(dot_fast_int8(x, w) ** 2)

    gx_c, gw_c = jax.grad(loss_cached, argnums=(0, 1))(x, w)
    gx_p, gw_p = jax.grad(loss_plain, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_c), np.asarray(gx_p), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gw_c), np.asarray(gw_p), rtol=1e-6)


# ---------------------------------------------------------------------------
# QuantizedWeightCache semantics
# ---------------------------------------------------------------------------


def test_cache_quantizes_once(rng):
    w = jnp.asarray(rng.uniform(-1, 1, (32, 48)), jnp.float32)
    cache = QuantizedWeightCache()
    a = cache.get("mlp/w_gate", w, axis=1)
    b = cache.get("mlp/w_gate", w, axis=1)
    assert cache.quantize_calls == 1 and cache.hits == 1
    assert a.q is b.q
    # a different level is a different entry
    cache.get("mlp/w_gate", w, level="q8_8", axis=1)
    assert cache.quantize_calls == 2
    # bit-identical to direct quantization
    direct = quantize_pow2(w, bits=8, axis=1)
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(direct.q))


def test_cache_coherent_across_level_switches(rng):
    """set_level / scoped engine.at never drop entries (they are
    per-level immutable); only barrier-mediated invalidation clears."""
    from repro.core.precision import MathEngine

    eng = MathEngine("f32")
    w = jnp.asarray(rng.uniform(-1, 1, (16, 24)), jnp.float32)
    eng.weight_cache.get("blk/w_up", w, level="q16_16", axis=1)
    assert len(eng.weight_cache) == 1

    eng.set_level("q16_16")
    eng.set_level("f32")
    with eng.at("q8_24"):
        assert len(eng.weight_cache) == 1   # scoping does not invalidate
    assert len(eng.weight_cache) == 1
    assert eng.weight_cache.quantize_calls == 1

    n_events = len(eng._barrier.events)
    lat = eng.invalidate_weights()
    assert lat >= 0.0
    assert len(eng.weight_cache) == 0
    assert len(eng._barrier.events) == n_events + 1  # went through the barrier

    # named invalidation only drops that param (all its levels)
    eng.weight_cache.get("a/w", w, level="q16_16", axis=1)
    eng.weight_cache.get("a/w", w, level="q8_8", axis=1)
    eng.weight_cache.get("b/w", w, level="q16_16", axis=1)
    eng.invalidate_weights("a/w")
    assert "a/w" not in eng.weight_cache
    assert "b/w" in eng.weight_cache


def test_attach_quantized_weights_swiglu(rng):
    """swiglu_mlp with attached weights = fused path; tracks both the
    unfused FAST path and the precise path within quantization error."""
    d, f, M = 64, 192, 24
    params = {
        "norm": jnp.zeros((d,)),
        "w_gate": jnp.asarray(rng.uniform(-1, 1, (d, f)) * 0.1, jnp.float32),
        "w_up": jnp.asarray(rng.uniform(-1, 1, (d, f)) * 0.1, jnp.float32),
        "w_down": jnp.asarray(rng.uniform(-1, 1, (f, d)) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.uniform(-1, 1, (2, M, d)), jnp.float32)

    cache = QuantizedWeightCache()
    qparams = attach_quantized_weights(params, cache)
    assert {"w_gate_q", "w_up_q", "w_down_q"} <= set(qparams)
    assert cache.quantize_calls == 3

    fused = np.asarray(swiglu_mlp(qparams, x, "fast"), np.float32)
    unfused = np.asarray(swiglu_mlp(params, x, "fast"), np.float32)
    precise = np.asarray(swiglu_mlp(params, x, "precise"), np.float32)
    scale = np.abs(precise).max()
    assert np.abs(fused - precise).max() < 0.1 * scale + 1e-3
    assert np.abs(fused - unfused).max() < 0.1 * scale + 1e-3


def test_attach_stacked_and_moe_shapes(rng):
    """Exponent axes follow 'everything but the contraction axis' so
    scanned slices broadcast: (P,d,f) -> (P,1,f); (P,E,d,f) -> (P,E,1,f)."""
    cache = QuantizedWeightCache()
    params = {
        "w_gate": jnp.asarray(rng.uniform(-1, 1, (3, 8, 16)), jnp.float32),
        "nested": {"w_down": jnp.asarray(rng.uniform(-1, 1, (3, 2, 16, 8)), jnp.float32)},
    }
    q = attach_quantized_weights(params, cache)
    assert q["w_gate_q"]["exp"].shape == (3, 1, 16)
    assert q["nested"]["w_down_q"]["exp"].shape == (3, 2, 1, 8)
    # per-(stack, channel) exponents equal slicewise 2-D quantization
    sl = quantize_pow2(params["w_gate"][1], bits=8, axis=1)
    np.testing.assert_array_equal(np.asarray(q["w_gate_q"]["q"][1]), np.asarray(sl.q))


# ---------------------------------------------------------------------------
# MoE fused expert path
# ---------------------------------------------------------------------------


def test_moe_fused_expert_path(rng):
    from repro.configs.mixtral_8x22b import CONFIG
    from repro.models.config import smoke_config
    from repro.models.layers import init_from_specs
    from repro.models.moe import moe_forward, moe_specs

    cfg = smoke_config(CONFIG)
    params = init_from_specs(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.uniform(-1, 1, (2, 16, cfg.d_model)), jnp.float32)

    qparams = attach_quantized_weights(params, QuantizedWeightCache())
    fused, aux_f = moe_forward(qparams, x, cfg, "fast")
    unfused, aux_u = moe_forward(params, x, cfg, "fast")
    precise, _ = moe_forward(params, x, cfg, "precise")

    f, u, p = (np.asarray(v, np.float32) for v in (fused, unfused, precise))
    scale = max(np.abs(p).max(), 1e-6)
    assert np.abs(f - p).max() < 0.15 * scale + 1e-3
    assert np.abs(f - u).max() < 0.15 * scale + 1e-3
    np.testing.assert_allclose(np.asarray(aux_f), np.asarray(aux_u), rtol=1e-5)


# ---------------------------------------------------------------------------
# decode: zero weight quantizations (the counting hook)
# ---------------------------------------------------------------------------


def _count_quantize_calls(monkeypatch):
    calls = {"weight": 0, "act": 0}
    orig = quantization.quantize_pow2

    def counting(x, bits=8, axis=None):
        calls["weight" if axis is not None else "act"] += 1
        return orig(x, bits=bits, axis=axis)

    monkeypatch.setattr(quantization, "quantize_pow2", counting)
    return calls


def test_decode_step_no_weight_requant(rng, monkeypatch):
    """The FAST decode graph with attached weights contains ZERO weight
    quantizations — asserted by counting quantize_pow2(axis != None)
    calls while tracing a fresh decode step.  The unfused graph
    requantizes every projection (the regression this PR removes)."""
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import decode_step, init_caches, init_params, prefill_step
    from repro.models.config import smoke_config

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(1))
    qparams = attach_quantized_weights(params, QuantizedWeightCache())
    caches = init_caches(cfg, 1, 32)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    _, caches = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg, mode="fast"))(
        qparams, toks, caches
    )

    calls = _count_quantize_calls(monkeypatch)
    tok = jnp.asarray([[5]], jnp.int32)
    pos = jnp.asarray([4], jnp.int32)

    fn_cached = jax.jit(lambda p, t, s, c: decode_step(p, t, s, c, cfg, mode="fast"))
    jax.block_until_ready(fn_cached(qparams, tok, pos, caches)[0])
    assert calls["weight"] == 0, f"cached decode quantized weights: {calls}"
    assert calls["act"] > 0  # activations still quantize per call

    calls["weight"] = calls["act"] = 0
    fn_plain = jax.jit(lambda p, t, s, c: decode_step(p, t, s, c, cfg, mode="fast"))
    jax.block_until_ready(fn_plain(params, tok, pos, caches)[0])
    assert calls["weight"] > 0  # the old path requantizes in-graph


def test_server_weight_cache_populated_once():
    """Server build quantizes each weight exactly once; generate()
    never grows the count (per-step requantization is gone)."""
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import init_params
    from repro.models.config import smoke_config
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(2))
    srv = BatchedServer(
        cfg, params, ServerConfig(max_batch=1, max_len=32, max_new=4, start_mode="q16_16")
    )
    cache = srv.engine.weight_cache
    built = cache.quantize_calls
    assert built > 0 and cache.hits == 0
    srv.generate([[1, 2, 3]])
    srv.generate([[4, 5, 6]])
    assert cache.quantize_calls == built


# ---------------------------------------------------------------------------
# vectorized sampling / host-sync removal
# ---------------------------------------------------------------------------


def test_server_greedy_matches_teacher_forcing_fast_level():
    """Greedy decode at the FAST level (fused path) must equal argmax of
    the FAST prefill at each position — prefill and decode share the
    fused kernel-equivalent path, so consistency is preserved."""
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import init_caches, init_params, prefill_step
    from repro.models.config import smoke_config
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompt = list(range(1, 8))
    srv = BatchedServer(
        cfg, params, ServerConfig(max_batch=1, max_len=64, max_new=4, start_mode="q16_16")
    )
    out = srv.generate([prompt])[0]

    seq = list(prompt)
    for _ in range(4):
        caches = init_caches(cfg, 1, 64)
        logits, _ = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg, mode="fast"))(
            srv.params, jnp.asarray([seq], jnp.int32), caches
        )
        seq.append(int(jnp.argmax(logits[0])))
    assert out == seq, (out, seq)


def test_server_eos_trimming():
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import init_params
    from repro.models.config import smoke_config
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(4))
    srv = BatchedServer(cfg, params, ServerConfig(max_batch=2, max_len=32, max_new=6))
    ref = srv.generate([[1, 2, 3], [3, 2, 1]])
    first_new = ref[0][3]
    srv2 = BatchedServer(
        cfg, params, ServerConfig(max_batch=2, max_len=32, max_new=6, eos_id=int(first_new))
    )
    out = srv2.generate([[1, 2, 3], [3, 2, 1]])
    # row 0 stops right at its first token == eos
    assert out[0] == [1, 2, 3, int(first_new)]
    # rows never exceed prompt + max_new, and eos appears at most once at the end
    for o, p in zip(out, [[1, 2, 3], [3, 2, 1]]):
        assert len(o) <= len(p) + 6
        assert int(first_new) not in o[len(p):-1]


def test_server_temperature_sampling_on_device():
    from repro.configs.gemma2_2b import CONFIG
    from repro.models import init_params
    from repro.models.config import smoke_config
    from repro.runtime.serve import BatchedServer, ServerConfig

    cfg = smoke_config(CONFIG)
    params = init_params(cfg, jax.random.PRNGKey(5))
    srv = BatchedServer(
        cfg, params,
        ServerConfig(max_batch=2, max_len=32, max_new=4, temperature=0.8, seed=7),
    )
    outs = srv.generate([[1, 2, 3], [4, 5]])
    assert all(len(o) > 0 for o in outs)
    for o in outs:
        assert all(0 <= t < cfg.vocab for t in o)
    # deterministic under a fixed seed
    outs2 = srv.generate([[1, 2, 3], [4, 5]])
    assert outs == outs2


# ---------------------------------------------------------------------------
# interpret auto-detection
# ---------------------------------------------------------------------------


def test_default_interpret_off_tpu(rng):
    from repro.compat import default_interpret

    assert default_interpret() is (jax.default_backend() != "tpu")
    # interpret=None flows through every kernel entrypoint
    x = rand_int8(rng, (8, 128))
    wg = rand_int8(rng, (128, 128))
    out = fused_swiglu_kernel_call(
        x, wg, wg, np.int32(-7), np.full((128,), -7, np.int32),
        np.full((128,), -7, np.int32), interpret=None,
    )
    assert out.shape == (8, 128)
