"""Paged cache pool: allocator invariants (property-based), prefix-hash
contract, CacheOps bit-identity with the legacy helpers, and
copy-on-write semantics.

The serving-level contracts (paged serving == contiguous/alone serving,
chunked prefill, sharing on == off) live in tests/test_paged_serving.py;
this module pins the host-side machinery underneath them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _pbt import given, settings, strategies as st
from repro.configs import smoke
from repro.models import init_caches, reset_cache_slot, write_cache_slot
from repro.runtime.cachepool import (
    ContiguousCacheOps,
    PageAllocator,
    PagedCachePool,
    PrefixCache,
    token_hash_chain,
)


# ---------------------------------------------------------------------------
# PageAllocator: free-list + refcount invariants
# ---------------------------------------------------------------------------


def _check_conservation(alloc):
    live = alloc.live()
    assert alloc.n_free + len(live) + 1 == alloc.n_pages
    assert 0 not in live  # the zero page is never handed out
    assert alloc.refcount[0] == 1


@settings(max_examples=40)
@given(st.integers(min_value=2, max_value=24), st.integers(min_value=0, max_value=2**31 - 1))
def test_allocator_invariants_under_churn(n_pages, seed):
    """Free-list conservation, no double allocation, refcounts never
    negative, and full churn drains the pool — under a random
    alloc/incref/decref schedule."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(n_pages)
    held = []  # one entry per reference we hold
    for _ in range(200):
        op = rng.integers(0, 3)
        if op == 0 and alloc.n_free:
            pid = alloc.alloc()
            assert pid != 0
            assert held.count(pid) == 0 or alloc.refcount[pid] > 1
            held.append(pid)
        elif op == 1 and held:
            pid = held[rng.integers(len(held))]
            alloc.incref(pid)
            held.append(pid)
        elif op == 2 and held:
            pid = held.pop(rng.integers(len(held)))
            freed = alloc.decref(pid)
            assert freed == (pid not in held)
        assert (alloc.refcount >= 0).all()
        _check_conservation(alloc)
    # full churn: release every reference -> pool completely free again
    while held:
        alloc.decref(held.pop())
    assert alloc.n_free == n_pages - 1
    assert alloc.live() == []


def test_allocator_no_double_allocation_exhaustive():
    alloc = PageAllocator(6)
    pids = [alloc.alloc() for _ in range(5)]
    assert sorted(pids) == [1, 2, 3, 4, 5]  # every page exactly once
    with pytest.raises(MemoryError):
        alloc.alloc()


def test_allocator_refcount_underflow_raises():
    alloc = PageAllocator(4)
    pid = alloc.alloc()
    alloc.decref(pid)
    with pytest.raises(ValueError):
        alloc.decref(pid)
    with pytest.raises(ValueError):
        alloc.incref(pid)  # incref on a FREE page is also a bug


def test_allocator_zero_page_pinned():
    alloc = PageAllocator(4)
    assert alloc.decref(0) is False
    alloc.incref(0)  # no-op by contract
    assert alloc.refcount[0] == 1


# ---------------------------------------------------------------------------
# the prefix-hash contract
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(st.integers(min_value=1, max_value=8), st.integers(min_value=0, max_value=2**31 - 1))
def test_hash_chain_prefix_property(page_size, seed):
    """Digest i is a pure function of tokens[0:(i+1)*page_size]: two
    sequences agree on digest i iff they agree on that whole prefix."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1000, size=4 * page_size + rng.integers(0, page_size)).tolist()
    b = list(a)
    flip = rng.integers(0, len(b))
    b[flip] = int(b[flip]) + 1
    ca, cb = token_hash_chain(a, page_size), token_hash_chain(b, page_size)
    assert len(ca) == len(a) // page_size
    assert ca == token_hash_chain(list(a), page_size)  # deterministic
    flip_page = flip // page_size
    for i in range(len(cb)):
        if i < flip_page:
            assert ca[i] == cb[i]
        else:
            assert ca[i] != cb[i]  # divergence propagates through the chain


def test_hash_chain_ignores_partial_tail():
    ps = 4
    assert token_hash_chain([1, 2, 3], ps) == []
    full = token_hash_chain([1, 2, 3, 4], ps)
    assert token_hash_chain([1, 2, 3, 4, 9, 9], ps) == full


# ---------------------------------------------------------------------------
# PrefixCache: longest-match, LRU, refcount ownership
# ---------------------------------------------------------------------------


def test_prefix_cache_longest_match_and_lru():
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc)
    toks = list(range(12))
    chain = token_hash_chain(toks, 4)  # 3 full pages
    pages = [alloc.alloc() for _ in range(3)]
    for i in range(1, 4):
        cache.insert(chain[i - 1], pages[:i])
    # cache holds 1+2+3 = 6 references on top of ours
    assert alloc.refcount[pages[0]] == 1 + 3
    assert alloc.refcount[pages[2]] == 1 + 1

    n, got = cache.match(chain)
    assert (n, list(got)) == (3, pages)
    n, got = cache.match(chain[:2])
    assert (n, list(got)) == (2, pages[:2])
    assert cache.match(token_hash_chain([9] * 8, 4)) == (0, ())

    # our references released: pages stay resident via the cache alone
    for p in pages:
        alloc.decref(p)
    assert alloc.live() != []
    while len(cache):
        cache.evict_lru()
    assert alloc.live() == []  # cache eviction returned everything


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prefix_cache_refcounts_never_negative(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(12)
    cache = PrefixCache(alloc)
    runs = []
    for _ in range(60):
        # insert contract: the caller extends a run that is still
        # RESIDENT (its pages live, held by the cache), like admission
        # extending a matched prefix
        resident = [r for r in runs if r[0] in cache._entries]
        op = rng.integers(0, 3)
        if op == 0 and alloc.n_free:
            pid = alloc.alloc()
            key = bytes(rng.integers(0, 256, 8, dtype=np.uint8))
            base = (list(resident[rng.integers(len(resident))][1])
                    if resident and rng.integers(2) else [])
            pages = base + [pid]
            cache.insert(key, pages)
            alloc.decref(pid)  # cache now the sole owner of the new page
            runs.append((key, pages))
        elif op == 1:
            cache.evict_lru()
        elif op == 2 and resident:
            key, pages = resident[rng.integers(len(resident))]
            cache.insert(key, pages)  # duplicate insert must not double-count
        assert (alloc.refcount >= 0).all()
        _check_conservation(alloc)
    cache.drop_all()
    assert alloc.live() == []


# ---------------------------------------------------------------------------
# ContiguousCacheOps == the legacy helpers, bit for bit
# ---------------------------------------------------------------------------


def _tree_equal(a, b):
    ok = True
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        ok &= bool((np.asarray(la) == np.asarray(lb)).all())
    return ok


@pytest.mark.parametrize("arch", ["gemma2-2b", "jamba-v0.1-52b"])
def test_contiguous_ops_bit_identical_to_helpers(arch):
    """The api_redesign safety proof: routing the server's cache
    lifecycle through ContiguousCacheOps changes NOTHING — every op
    produces the exact bits the historical helper calls produced."""
    cfg = smoke(arch)
    ops = ContiguousCacheOps(cfg, n_slots=3, max_len=32)
    key = jax.random.PRNGKey(0)

    pool_ops = ops.alloc()
    pool_ref = init_caches(cfg, 3, 32, dtype=jnp.float32)
    assert _tree_equal(pool_ops, pool_ref)

    # a fake "prefilled" single-request tree with recognizable bits
    single = jax.tree.map(
        lambda l: jax.random.normal(key, l.shape).astype(l.dtype),
        init_caches(cfg, 1, 32, dtype=jnp.float32),
    )
    pool_ops = ops.write(pool_ops, single, 1)
    pool_ref = write_cache_slot(pool_ref, single, 1)
    assert _tree_equal(pool_ops, pool_ref)

    assert _tree_equal(ops.read(pool_ops, 1),
                       jax.tree.map(lambda l: l[:, 1:2], pool_ref))

    snap = ops.snapshot(pool_ops, 1)
    pool_ops = ops.reset(pool_ops, 1)
    pool_ref = reset_cache_slot(pool_ref, cfg, 1)
    assert _tree_equal(pool_ops, pool_ref)

    pool_ops = ops.restore(pool_ops, snap, 1)
    pool_ref = write_cache_slot(pool_ref, single, 1)
    assert _tree_equal(pool_ops, pool_ref)


# ---------------------------------------------------------------------------
# PagedCachePool: gather/scatter + copy-on-write
# ---------------------------------------------------------------------------


def _mk_pool(arch="deepseek-7b", **kw):
    cfg = smoke(arch)
    pool = PagedCachePool(cfg, n_slots=2, max_len=32, page_size=8, **kw)
    return cfg, pool, pool.alloc()


def test_paged_empty_view_is_pristine():
    """An unallocated slot's gathered view == a freshly initialized
    contiguous cache (payload 0, pos sentinel -1) — the zero-page
    contract the model steps rely on."""
    cfg, pool, state = _mk_pool()
    view = pool.device_view(state, pool.device_tables())
    ref = init_caches(cfg, 2, 32, dtype=jnp.float32)
    assert _tree_equal(view, ref)


def test_paged_write_read_roundtrip_and_free():
    cfg, pool, state = _mk_pool()
    key = jax.random.PRNGKey(1)
    single = jax.tree.map(
        lambda l: jax.random.normal(key, l.shape).astype(l.dtype),
        init_caches(cfg, 1, 32, dtype=jnp.float32),
    )
    state = pool.write(state, single, 0)
    assert _tree_equal(pool.read(state, 0), single)
    # the OTHER slot still reads pristine
    assert _tree_equal(pool.read(state, 1),
                       init_caches(cfg, 1, 32, dtype=jnp.float32))
    # reset releases every page; a re-allocated slot reads pristine
    # again even though freed page payloads keep their stale bits
    state = pool.reset(state, 0)
    g = pool.groups["L32"]
    assert g["alloc"].live() == []
    assert (g["table"] == 0).all()
    assert _tree_equal(pool.read(state, 0),
                       init_caches(cfg, 1, 32, dtype=jnp.float32))


def test_paged_commit_rows_masked_lane_untouched():
    cfg, pool, state = _mk_pool()
    state = pool.ensure_rows(state, 0, 0, 0)
    state = pool.ensure_rows(state, 1, 0, 0)
    tables = pool.device_tables()
    view = pool.device_view(state, tables)
    poked = jax.tree.map(lambda l: l + 7 if l.dtype != jnp.int32 else l + 1,
                         view)
    pos = jnp.zeros((2,), jnp.int32)
    state2 = pool.commit_rows(state, tables, poked,
                              pos, jnp.asarray([True, False]))
    v2 = pool.device_view(state2, tables)
    for keyname, node in v2.items():
        for name, leaf in node.items():
            a, b = np.asarray(leaf), np.asarray(view[keyname][name])
            # lane 1 bit-identical; lane 0 row 0 changed
            assert (a[:, 1] == b[:, 1]).all(), (keyname, name)


def test_paged_copy_on_write():
    """A shared page is never written through: the writer gets a
    private copy, the other holder keeps the original bits, refcounts
    stay exact."""
    cfg, pool, state = _mk_pool()
    g = pool.groups["L32"]
    # slot 0 owns block 0; share that page into slot 1's table
    state = pool.ensure_rows(state, 0, 0, 7)
    pid = int(g["table"][0, 0])
    g["alloc"].incref(pid)
    g["table"][1, 0] = pid
    pool._dirty = True
    assert g["alloc"].refcount[pid] == 2

    before = np.asarray(pool.read(state, 0)["pos0"]["k"])

    # slot 1 wants to write rows 0..7 -> CoW must trigger
    state = pool.ensure_rows(state, 1, 0, 7)
    new_pid = int(g["table"][1, 0])
    assert new_pid != pid
    assert g["alloc"].refcount[pid] == 1
    assert g["alloc"].refcount[new_pid] == 1
    # the copy carries the shared bits; the original is untouched
    assert (np.asarray(pool.read(state, 1)["pos0"]["k"][:, :, :8])
            == np.asarray(pool.read(state, 0)["pos0"]["k"][:, :, :8])).all()
    assert (np.asarray(pool.read(state, 0)["pos0"]["k"]) == before).all()

    # exclusive pages do NOT re-copy
    state = pool.ensure_rows(state, 1, 0, 7)
    assert int(g["table"][1, 0]) == new_pid


def test_paged_prepare_admission_with_sharing():
    cfg, pool, state = _mk_pool(prefix_sharing=True)
    prompt = list(range(20))  # 2 full pages of 8 + partial tail
    state, matched, chain = pool.prepare_admission(state, 0, prompt)
    assert matched == 0 and len(chain) == 2
    assert pool.finish_admission(0, chain, matched) == 2

    # same prefix, different tail -> 2 pages reused
    state, matched2, chain2 = pool.prepare_admission(
        state, 1, list(range(16)) + [99, 98, 97, 96]
    )
    assert matched2 == 16
    g = pool.groups["L32"]
    assert g["table"][1, 0] == g["table"][0, 0]
    assert g["table"][1, 1] == g["table"][0, 1]
    # shared blocks are refcounted per holder: block 0's page is held
    # by both slots AND both cache entries (each entry refs every page
    # of its run); block 1's only by the i=2 entry
    assert g["alloc"].refcount[g["table"][0, 0]] == 4
    assert g["alloc"].refcount[g["table"][0, 1]] == 3

    # a full-page-aligned prompt never attaches its LAST page shared
    # (the first decode write must land on a private block)
    pool.free_slot(0)
    state, matched3, _ = pool.prepare_admission(state, 0, list(range(16)))
    assert matched3 == 8

    # full churn: free both slots + drop the prefix cache -> pool empty
    pool.free_slot(0)
    pool.free_slot(1)
    pool.prefix.drop_all()
    assert g["alloc"].live() == []


def test_paged_can_admit_pressure_and_eviction():
    cfg = smoke("deepseek-7b")
    # 5 pages: the zero page + one slot's worth of 4 blocks — tight on
    # purpose so admission pressure is reachable
    pool = PagedCachePool(cfg, n_slots=2, max_len=32, page_size=8,
                          n_pages=5, prefix_sharing=True)
    state = pool.alloc()
    g = pool.groups["L32"]

    state, m, chain = pool.prepare_admission(state, 0, list(range(20)))
    pool.finish_admission(0, chain, m)  # 3 pages live, 2 prefix entries
    pool.free_slot(0)
    # the prefix cache alone keeps its 2 full pages resident
    assert len(pool.prefix) == 2 and len(g["alloc"].live()) == 2
    # a disjoint 20-token prompt needs 3 pages but only 2 are free:
    # can_admit must evict LRU prefix entries to make room
    assert pool.can_admit(list(range(100, 120)))
    assert g["alloc"].n_free >= 3

    # an ACTIVE slot pins its pages — eviction cannot free them, so an
    # over-capacity ask stays rejected (admission waits for a finish)
    state, m, chain = pool.prepare_admission(state, 0, list(range(200, 220)))
    pool.finish_admission(0, chain, m)  # 3 live again
    assert not pool.can_admit(list(range(300, 320)))


def test_paged_rejects_sharing_on_windowed_or_ssm_models():
    for arch in ("gemma2-2b", "jamba-v0.1-52b"):
        with pytest.raises(ValueError, match="prefix_sharing"):
            PagedCachePool(smoke(arch), n_slots=2, max_len=32, page_size=4,
                           prefix_sharing=True)


def test_paged_page_size_must_divide_windows():
    with pytest.raises(ValueError, match="divide"):
        # gemma2 smoke window is 8; page_size 32 cannot tile it
        PagedCachePool(smoke("gemma2-2b"), n_slots=2, max_len=64, page_size=32)
