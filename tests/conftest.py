"""Test harness config.

IMPORTANT: no XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single CPU device.  Multi-device sharding
tests spawn subprocesses with their own XLA_FLAGS (see
tests/test_dryrun.py).

Property-based tests go through ``tests/_pbt.py``, which re-exports
hypothesis when installed and a deterministic fixed-seed shim when not
— the tier-1 suite must collect and pass either way.
"""

import numpy as np
import pytest
from _pbt import settings

# Keep hypothesis deadlines off: jit compilation on first example would
# blow any wall-clock deadline and has nothing to do with correctness.
settings.register_profile("repro", deadline=None, max_examples=60, derandomize=True)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)  # the paper's seed
