"""Q-format gradient compression (paper §8.6): correctness vs exact
pmean, error-feedback recirculation, and int8 wire payloads — run on an
8-device host mesh in a subprocess."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.optim.grad_compress import compressed_mean

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g_global = rng.normal(0, 1, (8, 64, 33)).astype(np.float32)  # per-device grads

def worker(g_local, r_local):
    grads = {"w": g_local}
    res = {"w": r_local}
    mean, new_res = compressed_mean(grads, res, "data", 8, bits=8)
    exact = {"w": jax.lax.pmean(g_local, "data")}
    return mean, new_res, exact

f = jax.jit(shard_map(worker, mesh=mesh,
    in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data"), P("data")),
    check_vma=False))
gl = jnp.asarray(g_global.reshape(8 * 64, 33))
rl = jnp.zeros_like(gl)
mean, new_res, exact = f(gl, rl)

mean_np = np.asarray(mean["w"]).reshape(8, 64, 33)[0]
exact_np = np.asarray(exact["w"]).reshape(8, 64, 33)[0]
rel = float(np.abs(mean_np - exact_np).mean() / np.abs(exact_np).mean())
res_norm = float(np.abs(np.asarray(new_res["w"])).mean())

# int8 payloads on the wire?
hlo = f.lower(gl, rl).compile().as_text()
s8_colls = sum(1 for l in hlo.splitlines()
               if ("all-to-all" in l or "all-gather" in l) and "s8[" in l)

# two rounds of error feedback shrink accumulated bias:
m1, r1, _ = f(gl, rl)
m2, r2, _ = f(gl, r1["w"])
two_round = np.asarray(m1["w"]).reshape(8,64,33)[0] + np.asarray(m2["w"]).reshape(8,64,33)[0]
bias2 = float(np.abs(two_round - 2 * exact_np).mean() / np.abs(exact_np).mean())

print("RESULT:" + json.dumps({"rel": rel, "res_norm": res_norm,
    "s8_colls": s8_colls, "bias2": bias2}))
"""


@pytest.fixture(scope="module")
def result():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SNIPPET], capture_output=True, text=True,
        cwd=Path(__file__).parent.parent, timeout=560, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_compressed_mean_close_to_exact(result):
    # two quantization stages (pre-wire int8 + requantized sum): the
    # grid of the summed stage is 2**(e+log2 n); ~5% relative on white
    # noise, recirculated by error feedback
    assert result["rel"] < 0.08, result


def test_error_feedback_state_nonzero(result):
    assert result["res_norm"] > 0  # quantization error is recirculated


def test_wire_payloads_are_int8(result):
    assert result["s8_colls"] >= 2, result  # all_to_all + all_gather in s8


def test_error_feedback_reduces_accumulated_bias(result):
    # with EF the accumulated two-round error stays SUBLINEAR: less
    # than 2x the single-round error (without EF it would be ~2x rel)
    assert result["bias2"] < 1.6 * result["rel"], result
