"""Telemetry subsystem: metrics registry + tracer units, and the four
serving-level acceptance contracts from the observability PR:

(a) the exported span tree RECONCILES with the scheduler/decoder stats
    on a mixed-length workload (paged chunked prefill) and on a
    speculative workload — every counted event has exactly one span;
(b) the exported trace is valid Chrome ``trace_event`` JSON (phase
    vocabulary, X-events carry ts/dur, async b/e pairs balance per id);
(c) telemetry DISABLED adds zero host syncs on the async decode path
    and the served tokens are bit-identical to telemetry ENABLED — the
    profiler tier observes, never perturbs;
(d) the trace-time retrace counter reproduces the counting-hook
    assertions the paged suite pins (zero chunk retraces after warmup),
    and the weight-cache counter aliases stay coherent with the
    registry they delegate to.
"""

import json

import jax
import pytest

from repro.configs import smoke
from repro.models import init_params
from repro.runtime.config import ServingConfig
from repro.runtime.scheduler import Request
from repro.runtime.serve import ContinuousBatchingServer
from repro.runtime.speculative import SpeculativeConfig
from repro.runtime.telemetry import (
    MetricsRegistry,
    TelemetryConfig,
    Tracer,
    render_prometheus,
)

MAX_LEN = 32
PROMPTS = [
    [1, 2, 3, 4, 5],
    [7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17],
    [3, 1, 4],
]
BUDGETS = [4, 2, 6]   # mixed budgets: slot churn + eviction under test

_MODELS = {}


def _model(arch="gemma2-2b"):
    if arch not in _MODELS:
        cfg = smoke(arch)
        _MODELS[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _requests(srv, speculative=False):
    return [
        Request(rid=srv.next_rid(), prompt=p, max_new=b,
                speculative=speculative)
        for p, b in zip(PROMPTS, BUDGETS)
    ]


def _spans(srv):
    """name -> count of complete (ph=X) spans in the exported trace."""
    counts = {}
    for ev in srv.telemetry.trace_export()["traceEvents"]:
        if ev["ph"] == "X":
            counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    return counts


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------


def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests", labelnames=("reason",))
    c.inc(reason="eos")
    c.inc(3, reason="budget")
    assert c.value(reason="eos") == 1
    assert c.value(reason="budget") == 3
    assert c.value(reason="never") == 0
    assert c.total() == 4
    with pytest.raises(ValueError):
        c.inc(-1, reason="eos")


def test_registry_rejects_kind_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("x_total", "x")
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")          # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", labelnames=("a",))  # label mismatch
    # get-or-create: same spec returns the same object
    assert reg.counter("x_total", "x") is reg.counter("x_total", "x")


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth")
    g.set(5)
    g.inc()
    g.dec(2)
    assert g.value() == 4


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    s = h.snapshot_series(())
    assert s["buckets"]["0.1"] == 1
    assert s["buckets"]["1.0"] == 3   # cumulative, not per-bucket
    assert s["buckets"]["10.0"] == 4
    assert s["buckets"]["+Inf"] == 5
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(56.05)


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("ticks_total", "decode ticks").inc(7)
    reg.counter("fin_total", "finishes", labelnames=("reason",)).inc(reason="eos")
    reg.histogram("t_s", "seconds", buckets=(0.5,)).observe(0.25)
    text = render_prometheus(reg)
    assert "# TYPE ticks_total counter" in text
    assert "ticks_total 7" in text
    assert 'fin_total{reason="eos"} 1' in text
    assert 't_s_bucket{le="0.5"} 1' in text
    assert 't_s_bucket{le="+Inf"} 1' in text
    assert "t_s_count 1" in text


def test_snapshot_shapes():
    reg = MetricsRegistry()
    reg.counter("a_total", "a").inc(2)
    reg.counter("b_total", "b", labelnames=("k",)).inc(k="x")
    snap = reg.snapshot()
    assert snap["a_total"] == 2
    assert snap["b_total"] == {"k=x": 1}


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------


def test_tracer_span_and_async_events():
    tr = Tracer()
    with tr.span("work", tid=2, args={"n": 3}):
        pass
    tr.async_begin("request", id=7, tid=1)
    tr.async_end("request", id=7, tid=1)
    tr.instant("switch", args={"slot": 0})
    tr.thread_name(2, "slot1")
    out = tr.export()
    evs = out["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    assert len(x) == 1 and x[0]["name"] == "work"
    assert x[0]["tid"] == 2 and x[0]["args"] == {"n": 3}
    assert x[0]["dur"] >= 0 and x[0]["ts"] >= 0
    assert [e["ph"] for e in evs if e.get("cat") == "request"] == ["b", "e"]
    assert out["displayTimeUnit"] == "ms"


def test_tracer_bounded_events():
    tr = Tracer(max_events=3)
    for i in range(10):
        tr.instant(f"i{i}")
    out = tr.export()
    assert len(out["traceEvents"]) == 3
    assert out["otherData"]["dropped_events"] == 7


def test_telemetry_config_validation():
    with pytest.raises(ValueError):
        TelemetryConfig(enabled=False, sync_device=True)


# ---------------------------------------------------------------------------
# (a) span tree reconciles with scheduler/decoder stats
# ---------------------------------------------------------------------------


def test_span_tree_reconciles_paged_mixed_workload():
    cfg, params = _model()
    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=2, max_len=MAX_LEN, cache="paged", page_size=4,
                      telemetry=TelemetryConfig(enabled=True, trace=True)),
    )
    reqs = _requests(srv)
    fins = srv.serve(reqs)
    assert sorted(fins) == sorted(r.rid for r in reqs)

    spans = _spans(srv)
    st = srv.stats
    assert spans.get("admit", 0) == st["prefills"] == len(reqs)
    assert spans.get("prefill-chunk", 0) == st["prefill_chunks"] > 0
    assert spans.get("decode-tick", 0) == st["decode_steps"] > 0
    assert spans.get("level-pass", 0) == st["level_passes"] > 0

    # request lifecycles: one b/e pair per request, ids == rids
    evs = srv.telemetry.trace_export()["traceEvents"]
    begins = [e["id"] for e in evs if e.get("cat") == "request" and e["ph"] == "b"]
    ends = [e["id"] for e in evs if e.get("cat") == "request" and e["ph"] == "e"]
    assert sorted(begins) == sorted(ends) == sorted(r.rid for r in reqs)

    # the snapshot agrees with the stats view of the same registry
    snap = srv.metrics_snapshot()
    assert snap["decode_ticks_total"] == st["decode_steps"]
    assert snap["prefills_total"] == st["prefills"]
    assert snap["tokens_generated_total"] == sum(
        f.n_generated for f in fins.values())
    assert snap["requests_finished_total"] == {
        "reason=max_new": len(reqs)}


def test_span_tree_reconciles_speculative_workload():
    cfg, params = _model()
    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=2, max_len=MAX_LEN,
                      speculative=SpeculativeConfig(k=2, max_len=MAX_LEN),
                      telemetry=TelemetryConfig(enabled=True, trace=True)),
    )
    fins = srv.serve(_requests(srv, speculative=True))
    assert len(fins) == len(PROMPTS)

    spans = _spans(srv)
    st = srv.stats
    assert st["spec_rounds"] > 0
    assert spans.get("spec-round", 0) == st["spec_rounds"]
    assert spans.get("draft", 0) == spans.get("verify", 0) == st["spec_rounds"]
    assert st["spec_drafted"] >= st["spec_accepted"] >= 0

    snap = srv.metrics_snapshot()
    assert snap["spec_rounds_total"] == st["spec_rounds"]
    assert snap["spec_drafted_total"] == st["spec_drafted"]
    assert snap["spec_accepted_total"] == st["spec_accepted"]


# ---------------------------------------------------------------------------
# (b) exported trace is valid Chrome trace_event JSON
# ---------------------------------------------------------------------------


def test_trace_export_is_valid_chrome_trace(tmp_path):
    cfg, params = _model()
    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=2, max_len=MAX_LEN, cache="paged", page_size=4,
                      telemetry=TelemetryConfig(enabled=True, trace=True)),
    )
    srv.serve(_requests(srv))

    path = tmp_path / "trace.json"
    srv.telemetry.write_trace(str(path))
    out = json.loads(path.read_text())  # round-trips through real JSON

    assert isinstance(out["traceEvents"], list) and out["traceEvents"]
    open_async = {}
    for ev in out["traceEvents"]:
        assert ev["ph"] in ("X", "b", "e", "i", "M")
        assert ev["pid"] == 1
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
            assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        if ev["ph"] == "b":
            key = (ev["cat"], ev["id"])
            open_async[key] = open_async.get(key, 0) + 1
        if ev["ph"] == "e":
            key = (ev["cat"], ev["id"])
            open_async[key] = open_async.get(key, 0) - 1
    assert all(v == 0 for v in open_async.values()), "unbalanced async pairs"
    # thread-name metadata present for the engine lane
    names = [e["args"]["name"] for e in out["traceEvents"] if e["ph"] == "M"]
    assert "engine" in names


# ---------------------------------------------------------------------------
# (c) disabled telemetry: zero extra host syncs, bit-identical tokens
# ---------------------------------------------------------------------------


def test_disabled_telemetry_is_inert():
    cfg, params = _model()

    def serve_with(enabled):
        srv = ContinuousBatchingServer(
            cfg, params,
            ServingConfig(n_slots=2, max_len=MAX_LEN, cache="paged",
                          page_size=4,
                          telemetry=TelemetryConfig(enabled=enabled,
                                                    trace=enabled)),
        )
        fins = srv.serve(_requests(srv))
        toks = [fins[r].tokens for r in sorted(fins)]
        return srv, toks

    srv_off, toks_off = serve_with(False)
    srv_on, toks_on = serve_with(True)

    # the profiler tier observes; it never changes what is served
    assert toks_on == toks_off

    # identical host-sync counts: spans and timers added NO device pulls
    # on the async decode path (eos/health/evict/spec are the only
    # sanctioned syncs, and they are counted identically on both sides)
    syncs_off = srv_off.metrics_snapshot().get("host_syncs_total", {})
    syncs_on = srv_on.metrics_snapshot().get("host_syncs_total", {})
    assert syncs_on == syncs_off
    # eviction syncs exactly once per finished request; no eos_id is set
    # so the only other sanctioned pull is the cadenced health sync
    assert syncs_off.get("kind=evict") == len(PROMPTS)
    assert set(syncs_off) <= {"kind=evict", "kind=health"}

    # disabled telemetry has no tracer; the export is empty but valid
    assert srv_off.telemetry.tracer is None
    assert srv_off.telemetry.trace_export()["traceEvents"] == []


# ---------------------------------------------------------------------------
# (d) retrace counter + weight-cache alias coherence
# ---------------------------------------------------------------------------


def test_retrace_counter_reproduces_chunk_trace_contract():
    cfg, params = _model()
    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=2, max_len=MAX_LEN, cache="paged", page_size=4,
                      prefill_chunk=4),
    )
    srv.serve(_requests(srv))  # warmup: one chunk trace per ladder level
    traced = srv._chunk_traces
    assert traced == len(srv.level_names)
    assert srv.metrics_snapshot()["retrace_total"]["step=chunk"] == traced

    # a second burst of different lengths must not retrace the chunk step
    srv.serve(_requests(srv))
    assert srv._chunk_traces == traced

    # decode/tick steps were traced too, and the registry saw them
    retrace = srv.metrics_snapshot()["retrace_total"]
    assert retrace.get("step=decode", 0) > 0
    assert retrace.get("step=tick", 0) > 0


def test_weight_cache_aliases_delegate_to_registry():
    cfg, params = _model()
    srv = ContinuousBatchingServer(
        cfg, params, ServingConfig(n_slots=2, max_len=MAX_LEN))
    srv.serve(_requests(srv))
    wc = srv.engine.weight_cache
    snap = srv.metrics_snapshot()
    assert wc.quantize_calls == snap["weight_quantize_total"] > 0
    assert wc.hits == snap["weight_cache_hits_total"]
    assert wc.registry is srv.telemetry.registry
