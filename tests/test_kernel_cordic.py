"""Pallas CORDIC kernels vs NumPy-int64 oracles (bit-exact) and vs
math truth; shape sweeps incl. padding tails and iteration counts —
for the sincos kernel and the universal (Walther-mode) op family."""

import math

import numpy as np
import pytest

from repro.core.cordic import atan2_q16, cordic_sincos_q16, tanh_q16
from repro.core.qformat import Q16_16, to_fixed
from repro.kernels.cordic import ops, ref
from repro.kernels.cordic.cordic import cordic_kernel_call
from repro.kernels.cordic.ref import cordic_sincos_ref
from repro.kernels.cordic.universal import UNARY_OPS, atan2_kernel_call, universal_kernel_call


SHAPES = [(128,), (4096,), (1000,), (7,), (33, 50), (2, 3, 129)]


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_bit_exact_vs_oracle(rng, shape):
    theta = rng.uniform(-4 * math.pi, 4 * math.pi, size=shape).astype(np.float32)
    theta_q = np.asarray(to_fixed(theta, Q16_16))
    got_s, got_c = cordic_kernel_call(theta_q)
    want_s, want_c = cordic_sincos_ref(theta_q)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("iterations", [8, 12, 16])
def test_iteration_sweep_bit_exact(rng, iterations):
    theta_q = np.asarray(
        to_fixed(rng.uniform(-3.2, 3.2, size=(513,)).astype(np.float32), Q16_16)
    )
    got_s, got_c = cordic_kernel_call(theta_q, iterations=iterations)
    want_s, want_c = cordic_sincos_ref(theta_q, iterations=iterations)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("block_rows", [8, 64, 256])
def test_block_shape_sweep(rng, block_rows):
    theta_q = np.asarray(
        to_fixed(rng.uniform(-3.2, 3.2, size=(5000,)).astype(np.float32), Q16_16)
    )
    got_s, got_c = cordic_kernel_call(theta_q, block_rows=block_rows)
    want_s, want_c = cordic_sincos_ref(theta_q)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


def test_kernel_matches_pure_jax_core(rng):
    """kernels/cordic and core/cordic implement the same contract."""
    theta_q = np.asarray(
        to_fixed(rng.uniform(-10, 10, size=(777,)).astype(np.float32), Q16_16)
    )
    ks, kc = cordic_kernel_call(theta_q)
    cs, cc = cordic_sincos_q16(theta_q)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(cs))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(cc))


def test_float_boundary_accuracy(rng):
    theta = rng.uniform(-math.pi, math.pi, size=(2048,)).astype(np.float32)
    s, c = ops.sincos(theta)
    np.testing.assert_allclose(np.asarray(s), np.sin(theta), atol=8e-4)
    np.testing.assert_allclose(np.asarray(c), np.cos(theta), atol=8e-4)


def test_rope_tables_long_context():
    """RoPE tables at 500k-scale positions stay accurate (the fp32
    failure mode this path exists to fix)."""
    from repro.core.cordic import rope_inv_freq_q64

    f_hi, f_lo = rope_inv_freq_q64(128, base=10000.0)
    pos = np.array([0, 1, 524286, 524287], np.uint32)
    sin, cos = ops.rope_tables(pos, f_hi, f_lo)
    assert sin.shape == (4, 64)
    for i, p in enumerate(pos):
        for j in (1, 7, 31):
            inv_freq = 10000.0 ** (-2.0 * j / 128)
            angle = math.fmod(int(p) * inv_freq, 2 * math.pi)
            assert float(np.asarray(sin)[i, j]) == pytest.approx(math.sin(angle), abs=1e-3)
            assert float(np.asarray(cos)[i, j]) == pytest.approx(math.cos(angle), abs=1e-3)


# ---------------------------------------------------------------------------
# universal (Walther-mode) kernels: interpret-mode sweeps vs int64 oracles
# ---------------------------------------------------------------------------


def _rand_q16(rng, shape, lo, hi):
    return np.round(rng.uniform(lo, hi, size=shape) * 65536.0).astype(np.int32)


@pytest.mark.parametrize("op", sorted(UNARY_OPS))
@pytest.mark.parametrize("shape", [(512,), (1000,), (7,), (9, 33)])
def test_universal_unary_bit_exact_vs_oracle(rng, op, shape):
    lo, hi = (0.0, 30000.0) if op in ("sqrt", "log") else (-20.0, 20.0)
    w = _rand_q16(rng, shape, lo, hi)
    got = np.asarray(universal_kernel_call(w, op=op))
    want = ref.UNARY_REFS[op](w)
    assert got.dtype == np.int32 and got.shape == shape
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [(128,), (777,), (5, 129)])
def test_atan2_kernel_bit_exact_vs_oracle(rng, shape):
    y = _rand_q16(rng, shape, -100.0, 100.0)
    x = _rand_q16(rng, shape, -100.0, 100.0)
    got = np.asarray(atan2_kernel_call(y, x))
    np.testing.assert_array_equal(got, ref.atan2_ref(y, x))


@pytest.mark.parametrize("block_rows", [8, 64])
def test_universal_block_sweep(rng, block_rows):
    w = _rand_q16(rng, (3000,), 0.0, 100.0)
    got = np.asarray(universal_kernel_call(w, op="sqrt", block_rows=block_rows))
    np.testing.assert_array_equal(got, ref.sqrt_ref(w))


@pytest.mark.parametrize("stages", [16, 20])
def test_universal_stage_sweep(rng, stages):
    t = _rand_q16(rng, (513,), -5.0, 5.0)
    got = np.asarray(universal_kernel_call(t, op="exp", stages=stages))
    np.testing.assert_array_equal(got, ref.exp_ref(t, stages=stages))


def test_universal_kernel_matches_core(rng):
    """kernels/cordic/universal and core/cordic share one contract."""
    t = _rand_q16(rng, (640,), -10.0, 10.0)
    np.testing.assert_array_equal(
        np.asarray(universal_kernel_call(t, op="tanh")), np.asarray(tanh_q16(t))
    )
    y = _rand_q16(rng, (640,), -10.0, 10.0)
    np.testing.assert_array_equal(
        np.asarray(atan2_kernel_call(y, t)), np.asarray(atan2_q16(y, t))
    )


def test_universal_padding_is_total(rng):
    """Non-multiple-of-block sizes exercise the zero padding: every op
    must be well-defined at 0 and the tail must not leak into outputs."""
    for op in sorted(UNARY_OPS):
        w = _rand_q16(rng, (130,), 0.5, 10.0)
        a = np.asarray(universal_kernel_call(w, op=op, block_rows=8))
        b = ref.UNARY_REFS[op](w)
        np.testing.assert_array_equal(a, b)


def test_universal_float_boundaries(rng):
    y = rng.uniform(-50, 50, (2048,)).astype(np.float32)
    x = rng.uniform(-50, 50, (2048,)).astype(np.float32)
    got = np.asarray(ops.atan2(y, x))
    np.testing.assert_allclose(got, np.arctan2(y, x), atol=2e-4)
    w = rng.uniform(0.01, 1000.0, (2048,)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.unary_op(w, "sqrt")), np.sqrt(w), atol=5e-2)
    np.testing.assert_allclose(np.asarray(ops.unary_op(w, "log")), np.log(w), atol=2e-4)


def test_universal_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown universal op"):
        universal_kernel_call(np.zeros((8,), np.int32), op="cbrt")
