"""Pallas CORDIC kernel vs NumPy-int64 oracle (bit-exact) and vs
math truth; shape sweeps incl. padding tails and iteration counts."""

import math

import numpy as np
import pytest

from repro.core.cordic import cordic_sincos_q16
from repro.core.qformat import Q16_16, to_fixed
from repro.kernels.cordic import ops
from repro.kernels.cordic.cordic import cordic_kernel_call
from repro.kernels.cordic.ref import cordic_sincos_ref


SHAPES = [(128,), (4096,), (1000,), (7,), (33, 50), (2, 3, 129)]


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_bit_exact_vs_oracle(rng, shape):
    theta = rng.uniform(-4 * math.pi, 4 * math.pi, size=shape).astype(np.float32)
    theta_q = np.asarray(to_fixed(theta, Q16_16))
    got_s, got_c = cordic_kernel_call(theta_q)
    want_s, want_c = cordic_sincos_ref(theta_q)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("iterations", [8, 12, 16])
def test_iteration_sweep_bit_exact(rng, iterations):
    theta_q = np.asarray(
        to_fixed(rng.uniform(-3.2, 3.2, size=(513,)).astype(np.float32), Q16_16)
    )
    got_s, got_c = cordic_kernel_call(theta_q, iterations=iterations)
    want_s, want_c = cordic_sincos_ref(theta_q, iterations=iterations)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("block_rows", [8, 64, 256])
def test_block_shape_sweep(rng, block_rows):
    theta_q = np.asarray(
        to_fixed(rng.uniform(-3.2, 3.2, size=(5000,)).astype(np.float32), Q16_16)
    )
    got_s, got_c = cordic_kernel_call(theta_q, block_rows=block_rows)
    want_s, want_c = cordic_sincos_ref(theta_q)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


def test_kernel_matches_pure_jax_core(rng):
    """kernels/cordic and core/cordic implement the same contract."""
    theta_q = np.asarray(
        to_fixed(rng.uniform(-10, 10, size=(777,)).astype(np.float32), Q16_16)
    )
    ks, kc = cordic_kernel_call(theta_q)
    cs, cc = cordic_sincos_q16(theta_q)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(cs))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(cc))


def test_float_boundary_accuracy(rng):
    theta = rng.uniform(-math.pi, math.pi, size=(2048,)).astype(np.float32)
    s, c = ops.sincos(theta)
    np.testing.assert_allclose(np.asarray(s), np.sin(theta), atol=8e-4)
    np.testing.assert_allclose(np.asarray(c), np.cos(theta), atol=8e-4)


def test_rope_tables_long_context():
    """RoPE tables at 500k-scale positions stay accurate (the fp32
    failure mode this path exists to fix)."""
    from repro.core.cordic import rope_inv_freq_q64

    f_hi, f_lo = rope_inv_freq_q64(128, base=10000.0)
    pos = np.array([0, 1, 524286, 524287], np.uint32)
    sin, cos = ops.rope_tables(pos, f_hi, f_lo)
    assert sin.shape == (4, 64)
    for i, p in enumerate(pos):
        for j in (1, 7, 31):
            inv_freq = 10000.0 ** (-2.0 * j / 128)
            angle = math.fmod(int(p) * inv_freq, 2 * math.pi)
            assert float(np.asarray(sin)[i, j]) == pytest.approx(math.sin(angle), abs=1e-3)
            assert float(np.asarray(cos)[i, j]) == pytest.approx(math.cos(angle), abs=1e-3)
