"""Ladder-speculative decoding exactness suite (driven by the
reusable harness in tests/spec_harness.py).

The contract under test: drafting at a cheap rung and verifying at f32
changes HOW FAST tokens appear, never WHICH tokens — the speculative
stream is token-for-token identical to vanilla f32 greedy decode, the
caches after a round are bit-identical to sequentially decoding only
the accepted tokens, and the acceptance accounting matches a NumPy
reference simulator.  Swept over every cache architecture (SWA, hybrid
SSM, MLA) x draft rungs x seeds, plus the continuous-batching server
integration (spec slots exact under churn and in mixed traffic).
"""

import functools

import jax
import numpy as np
import pytest

from repro.models import init_params, smoke_config
from repro.runtime.scheduler import Request
from repro.runtime.serve import ContinuousBatchingServer, ContinuousServerConfig
from repro.runtime.speculative import (
    SPEC_DRAFT_LEVELS,
    LadderSpeculativeDecoder,
    SpeculativeConfig,
    register_spec_steps,
)
from repro.core.precision import MathEngine

from spec_harness import (
    DRAFT_RUNGS,
    FAMILIES,
    ExactnessHarness,
    family_config,
    make_prompts,
    simulate_acceptance,
)

SEEDS = (0, 1, 2, 3)


@functools.lru_cache(maxsize=None)
def harness(family: str, k: int = 3) -> ExactnessHarness:
    """One compiled harness per (family, k), shared across the sweep."""
    return ExactnessHarness(family, k=k)


# ---------------------------------------------------------------------------
# property 1: token exactness (3 families x 2 rungs x 4 seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("rung", DRAFT_RUNGS)
@pytest.mark.parametrize("seed", SEEDS)
def test_token_exactness(family, rung, seed):
    rep = harness(family).run_exactness(rung, seed)
    assert rep.tokens_ok, (
        f"{family}/{rung}/seed{seed}: speculative != vanilla f32 greedy\n"
        f"  spec    {rep.speculative}\n  vanilla {rep.vanilla}"
    )
    # accounting: decoder counters == NumPy simulator replay of the trace
    assert rep.accounting_ok, (rep.accounting, rep.simulator)
    assert rep.accounting["rounds"] == rep.simulator["rounds"]
    # every committed token is f32-verified, so each round commits >= 1
    # per active lane: rounds never exceed total tokens emitted
    assert 0.0 <= rep.acceptance_rate <= 1.0


def test_acceptance_rates_vary_across_rungs_and_families():
    """Sanity that the sweep exercises real speculation dynamics: the
    measured acceptance rates are neither all-0 (drafts useless —
    machinery untested beyond the trivial path) nor all-1 (rollback
    never exercised)."""
    rates = []
    for family in FAMILIES:
        for rung in DRAFT_RUNGS:
            rep = harness(family).run_exactness(rung, seed=0)
            rates.append(rep.acceptance_rate)
    assert any(r > 0.0 for r in rates), rates
    assert any(r < 1.0 for r in rates), rates


# ---------------------------------------------------------------------------
# property 2: cache rollback bit-identity after a REAL round
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("seed", (0, 1))
def test_rollback_cache_bit_identity(family, seed):
    res = harness(family).run_rollback("q8_8", seed)
    assert res["commit_bit_identical"], (
        f"{family}/seed{seed}: committed caches != sequential-decode caches"
    )
    assert res["rejected_restored"]


def test_rollback_sweep_includes_real_rejections():
    """The bit-identity property is only meaningful if some round in
    the sweep actually rejected drafts; check that across seeds at the
    cheapest rung at least one rejection occurred per family."""
    for family in FAMILIES:
        h = harness(family)
        assert any(
            h.run_rollback("q8_8", seed)["had_rejections"] for seed in (0, 1, 2)
        ), f"{family}: no rejections in 3 seeds — sweep too easy"


# ---------------------------------------------------------------------------
# property 3 (edge): the simulator itself, on hand-built traces
# ---------------------------------------------------------------------------


def test_simulator_hand_built_rounds():
    k = 3
    trace = [
        {  # lane0: all k accepted; lane1: first draft wrong; lane2 inactive
            "drafts": np.array([[5, 6, 7], [5, 6, 7], [1, 1, 1]]),
            "preds": np.array([[5, 6, 7, 8], [9, 6, 7, 8], [1, 1, 1, 1]]),
            "active": np.array([True, True, False]),
        },
        {  # agreement only resumes counting from the start (prefix!)
            "drafts": np.array([[4, 4, 4], [2, 9, 9], [1, 1, 1]]),
            "preds": np.array([[9, 4, 4, 4], [2, 9, 0, 0], [1, 1, 1, 1]]),
            "active": np.array([True, True, False]),
        },
    ]
    sim = simulate_acceptance(trace, k)
    assert sim["rounds"] == 2
    assert sim["drafted"] == 4 * k
    # round1: 3 + 0; round2: 0 (first mismatch) + 2
    assert sim["accepted"] == 5
    assert sim["n_commit"][0].tolist() == [4, 1, 0]
    assert sim["n_commit"][1].tolist() == [1, 3, 0]


# ---------------------------------------------------------------------------
# k variation + config validation
# ---------------------------------------------------------------------------


def test_k_variation_token_exactness():
    """k=1 (degenerate: one draft per round) and k=5 must both match
    k=3's output exactly — k is a throughput knob, not a semantics one."""
    base = harness("gemma2_2b").run_exactness("q16_16", seed=0)
    for k in (1, 5):
        rep = harness("gemma2_2b", k).run_exactness("q16_16", seed=0)
        assert rep.tokens_ok
        assert rep.speculative == base.speculative, f"k={k} changed tokens"
        assert rep.accounting_ok


def test_speculative_config_validation():
    with pytest.raises(ValueError, match="k must be >= 1"):
        SpeculativeConfig(k=0)
    with pytest.raises(ValueError, match="not a draft rung"):
        SpeculativeConfig(draft_level="f32")  # verify rung can't draft
    with pytest.raises(ValueError, match="not a draft rung"):
        SpeculativeConfig(draft_level="nope")


def test_k_must_fit_smallest_attention_window():
    """A verify segment wider than the rolling KV window would wrap
    onto positions the verify still attends to — rejected at build."""
    cfg = family_config("gemma2_2b")  # smoke window = 8
    w = min(l.window for l in cfg.period if l.window is not None)
    with pytest.raises(ValueError, match="smallest attention window"):
        register_spec_steps(MathEngine("q8_8"), cfg, k=w)


def test_generate_rejects_insufficient_headroom():
    h = harness("gemma2_2b")
    dec = h.decoder("q8_8")
    with pytest.raises(ValueError, match="headroom"):
        dec.generate([[1, 2, 3]], max_new=200)


# ---------------------------------------------------------------------------
# EOS semantics
# ---------------------------------------------------------------------------


def test_eos_truncates_like_vanilla():
    """With an EOS id that actually fires, the speculative stream must
    stop exactly where vanilla stops — even when the EOS token was
    committed mid-round with further verified tokens behind it."""
    h = harness("jamba_v01_52b")
    rep = h.run_exactness("q8_8", seed=2, max_new=16)
    ref = rep.vanilla
    # pick an EOS id that appears in some reference stream (not at the
    # very start); fall back to a non-appearing id (pure budget stop)
    eos = None
    for toks in ref:
        for t in toks[1:]:
            eos = t
            break
        if eos is not None:
            break
    dec = LadderSpeculativeDecoder(
        h.cfg, h.params,
        SpeculativeConfig(k=3, draft_level="q8_8", max_len=64, eos_id=eos),
    )
    got = dec.generate(make_prompts(h.cfg.vocab, 2), max_new=16)
    for g, r in zip(got, ref):
        if eos in r:
            assert g == r[: r.index(eos) + 1]  # EOS kept, nothing after
        else:
            assert g == r


# ---------------------------------------------------------------------------
# serving integration: spec slots under continuous-batching churn
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def spec_server_model():
    cfg = family_config("gemma2_2b")
    params = init_params(cfg, jax.random.PRNGKey(5))
    return cfg, params


def test_server_speculative_matches_vanilla_f32_serving(spec_server_model):
    """5 requests on 3 slots (continuous churn): every speculative
    request's output equals the vanilla f32 server's, and the server
    actually speculated (accepted drafts > 0)."""
    cfg, params = spec_server_model
    prompts = make_prompts(cfg.vocab, 7) + make_prompts(cfg.vocab, 8)[:2]

    ref = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=3, max_len=64)
    ).generate(prompts, max_new=12, level="f32")

    srv = ContinuousBatchingServer(
        cfg, params,
        ContinuousServerConfig(
            n_slots=3, max_len=64,
            speculative=SpeculativeConfig(k=3, draft_level="q8_8", max_len=64),
        ),
    )
    got = srv.generate(prompts, max_new=12, speculative=True)
    assert got == ref
    assert srv.stats["spec_rounds"] > 0
    assert 0 < srv.stats["spec_accepted"] <= srv.stats["spec_drafted"]


def test_server_mixed_spec_and_vanilla_traffic(spec_server_model):
    """Speculative and vanilla requests share the same slot pool; the
    spec lanes still emit exactly the vanilla f32 stream."""
    cfg, params = spec_server_model
    prompts = make_prompts(cfg.vocab, 9)
    ref = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=2, max_len=64)
    ).generate(prompts, max_new=8, level="f32")

    srv = ContinuousBatchingServer(
        cfg, params,
        ContinuousServerConfig(
            n_slots=2, max_len=64,
            speculative=SpeculativeConfig(k=3, draft_level="q8_8", max_len=64),
        ),
    )
    reqs = [
        Request(rid=i, prompt=list(p), max_new=8,
                speculative=(i % 2 == 0),
                level=None if i % 2 == 0 else "q16_16")
        for i, p in enumerate(prompts)
    ]
    fins = srv.serve(reqs)
    for i, p in enumerate(prompts):
        if i % 2 == 0:
            assert fins[i].tokens == ref[i], f"spec lane {i} diverged"
        else:
            assert fins[i].n_generated == 8  # vanilla lanes still served


def test_server_rejects_spec_request_without_spec_config(spec_server_model):
    cfg, params = spec_server_model
    srv = ContinuousBatchingServer(
        cfg, params, ContinuousServerConfig(n_slots=1, max_len=64)
    )
    with pytest.raises(ValueError, match="speculative"):
        srv.serve([Request(rid=0, prompt=[1, 2], max_new=2, speculative=True)])
    assert not srv.scheduler.has_work()  # nothing stranded


def test_server_low_acceptance_escalates_draft_rung(spec_server_model):
    """The measured acceptance rate is a live precision signal: a slot
    whose drafts keep missing has its DRAFT rung escalated by the
    draft arbiter (verify rung stays f32 — exactness is never at stake)."""
    cfg, params = spec_server_model
    from repro.core.arbiter import SlotArbiterConfig

    srv = ContinuousBatchingServer(
        cfg, params,
        ContinuousServerConfig(
            n_slots=1, max_len=64,
            speculative=SpeculativeConfig(k=3, draft_level="q8_8", max_len=64),
            arbiter=SlotArbiterConfig(
                n_levels=2, accept_threshold=1.01,  # every round is "low"
                accept_patience=1, cooldown_steps=1, stable_steps=10**6,
            ),
        ),
    )
    names = tuple(lv for lv, _ in SPEC_DRAFT_LEVELS)
    assert srv.draft_arbiter.idx[0] == names.index("q8_8")
    fins = srv.serve([Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=10,
                              speculative=True)])
    assert fins[0].n_generated == 10
    assert srv.draft_arbiter.idx[0] == names.index("q16_16")  # escalated
    assert any(reason == "acceptance" for *_, reason in srv.draft_arbiter.switches)
