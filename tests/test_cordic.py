"""C2 validation: CORDIC sincos vs math oracle; paper §3.2 bounds and
§5.2 constants; the exact long-context RoPE phase (beyond paper)."""

import math

import numpy as np
import pytest
from _pbt import given, strategies as st

from repro.core import cordic as cd
from repro.core.qformat import Q16_16, from_fixed, to_fixed


# ---------------------------------------------------------------------------
# paper §5.2 constants
# ---------------------------------------------------------------------------


def test_paper_constants():
    assert cd.CORDIC_K_INV_Q16 == 39797
    assert cd.PI_Q16 == 205887
    assert cd.HALF_PI_Q16 == 102944
    assert cd.TWO_PI_Q16 == 411775
    assert list(cd.ATAN_TABLE_Q16[:7]) == [51472, 30386, 16055, 8150, 4091, 2047, 1024]
    # paper §4.3.2: the table is 64 bytes of rodata
    assert cd.ATAN_TABLE_Q16.nbytes == 64


def test_gain_limit():
    # K_n -> 1.6467602 (paper Eq. 13)
    k_inv = cd.gain_inverse(32, frac_bits=30) / (1 << 30)
    assert 1.0 / k_inv == pytest.approx(1.6467602, abs=1e-6)


# ---------------------------------------------------------------------------
# accuracy: angular bound (Eq. 14) + Q16.16 datapath rounding
# ---------------------------------------------------------------------------

# The pure angular bound is 2**-16 rad; the fixed-point datapath adds
# bounded shift-rounding noise (~n * ulp amplified by the gain), giving
# a practical bound near 6e-4 absolute. Measured max in
# benchmarks/bench_trig.py; asserted conservatively here.
ABS_TOL = 8e-4


def test_dense_grid_accuracy():
    theta = np.linspace(-math.pi, math.pi, 4001).astype(np.float32)
    s, c = cd.cordic_sincos(theta)
    np.testing.assert_allclose(np.asarray(s), np.sin(theta), atol=ABS_TOL)
    np.testing.assert_allclose(np.asarray(c), np.cos(theta), atol=ABS_TOL)


def test_full_turn_range_reduction():
    """Any int32 Q16.16 angle is accepted (listing assumed [-pi, pi])."""
    theta = np.linspace(-300.0, 300.0, 2001).astype(np.float32)
    s, c = cd.cordic_sincos(theta)
    np.testing.assert_allclose(np.asarray(s), np.sin(theta), atol=2e-3)
    np.testing.assert_allclose(np.asarray(c), np.cos(theta), atol=2e-3)


@given(st.floats(min_value=-math.pi, max_value=math.pi, allow_nan=False))
def test_pythagorean_identity(theta):
    s, c = cd.cordic_sincos(np.float32(theta))
    assert float(s) ** 2 + float(c) ** 2 == pytest.approx(1.0, abs=4e-3)


def test_sin_negation_fold_bug_fixed():
    """Paper Listing 2 claims sin needs no negation after the theta -> theta-pi
    fold; that is wrong (sin(t-pi) = -sin t). Verify our fold is correct
    in the second/third quadrants where the bug would bite."""
    theta = np.array([2.0, 2.5, 3.0, -2.0, -2.5, -3.0], np.float32)
    s, _ = cd.cordic_sincos(theta)
    np.testing.assert_allclose(np.asarray(s), np.sin(theta), atol=ABS_TOL)
    # sign must match exactly in these quadrants
    assert np.all(np.sign(np.asarray(s)) == np.sign(np.sin(theta)))


def test_iteration_convergence():
    """Error shrinks ~2**-n with iteration count (paper Eq. 14 scaling),
    until the Q16.16 datapath floor is reached."""
    theta = np.linspace(-1.5, 1.5, 512).astype(np.float32)
    errs = []
    for n in (4, 8, 12):
        s, _ = cd.cordic_sincos(theta, iterations=n)
        errs.append(np.max(np.abs(np.asarray(s) - np.sin(theta))))
    assert errs[0] > errs[1] > errs[2]
    assert errs[1] / errs[0] < 0.15  # ~2**-4 per 4 iterations


def test_determinism_bitwise():
    """The TPU analogue of the paper's Determinism Score 0.994: the
    computation is bit-deterministic (same input -> same raw Q output)."""
    theta_q = to_fixed(np.linspace(-3, 3, 257).astype(np.float32), Q16_16)
    s1, c1 = cd.cordic_sincos_q16(theta_q)
    s2, c2 = cd.cordic_sincos_q16(theta_q)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))


# ---------------------------------------------------------------------------
# cordic_rotate: data rotation (RoPE application primitive)
# ---------------------------------------------------------------------------


@given(
    st.floats(-1.0, 1.0, allow_nan=False),
    st.floats(-1.0, 1.0, allow_nan=False),
    st.floats(-math.pi, math.pi, allow_nan=False),
)
def test_rotate_matches_rotation_matrix(x, y, theta):
    xq, yq = to_fixed(np.float32(x)), to_fixed(np.float32(y))
    tq = to_fixed(np.float32(theta))
    xr, yr = cd.cordic_rotate_q16(xq, yq, tq)
    want_x = x * math.cos(theta) - y * math.sin(theta)
    want_y = x * math.sin(theta) + y * math.cos(theta)
    assert float(from_fixed(xr)) == pytest.approx(want_x, abs=2e-3)
    assert float(from_fixed(yr)) == pytest.approx(want_y, abs=2e-3)


# ---------------------------------------------------------------------------
# exact RoPE phase accumulation (beyond paper)
# ---------------------------------------------------------------------------


def test_exact_phase_matches_python_ints():
    """The Q0.64 limb path must equal exact integer arithmetic."""
    head_dim = 64
    f_hi, f_lo = cd.rope_inv_freq_q64(head_dim, base=10000.0)
    positions = np.array([0, 1, 2, 1000, 524287, 524288], np.uint32)
    theta = np.asarray(cd.exact_rope_phase_q16(positions[:, None], f_hi[None, :], f_lo[None, :]))
    for i, pos in enumerate(positions):
        for j in range(head_dim // 2):
            f = (int(f_hi[j]) << 32) | int(f_lo[j])
            frac64 = (int(pos) * f) & ((1 << 64) - 1)
            frac32 = frac64 >> 32
            want = (frac32 * cd.TWO_PI_Q16 + (1 << 31)) >> 32
            assert int(theta[i, j]) == want, (pos, j)


def test_long_context_phase_beats_float32():
    """At pos = 524288 the fp32 product pos*inv_freq loses ~5 bits before
    the mod; the fixed-point path must be orders of magnitude closer to
    the exact phase."""
    head_dim = 128
    base = 10000.0
    f_hi, f_lo = cd.rope_inv_freq_q64(head_dim, base)
    pos = 524288 - 1
    # j=1: the fastest frequency whose inv_freq is NOT exactly
    # representable in fp32 (j=0 gives exactly 1.0, which is error-free).
    j = 1
    inv_freq = base ** (-2.0 * j / head_dim)

    # ground truth with python floats (exact integer pos, float64 mod)
    exact_angle = math.fmod(pos * inv_freq, 2 * math.pi)

    # fp32 baseline: the standard RoPE computation
    fp32_angle = math.fmod(float(np.float32(pos) * np.float32(inv_freq)), 2 * math.pi)
    fp32_err = abs(fp32_angle - exact_angle)

    theta_q = cd.exact_rope_phase_q16(
        np.uint32(pos), np.uint32(f_hi[j]), np.uint32(f_lo[j])
    )
    ours = float(int(theta_q)) / 65536.0
    ours_err = min(
        abs(ours - exact_angle), abs(ours - exact_angle - 2 * math.pi),
        abs(ours - exact_angle + 2 * math.pi),
    )
    assert ours_err < 5e-5
    assert fp32_err > 50 * ours_err, (fp32_err, ours_err)


def test_rope_tables_shapes_and_identity():
    f_hi, f_lo = cd.rope_inv_freq_q64(64)
    pos = np.arange(128, dtype=np.uint32)
    sin, cos = cd.rope_tables_cordic(pos, f_hi, f_lo)
    assert sin.shape == (128, 32) and cos.shape == (128, 32)
    np.testing.assert_allclose(np.asarray(sin) ** 2 + np.asarray(cos) ** 2, 1.0, atol=5e-3)
    # position 0 -> angle 0
    np.testing.assert_allclose(np.asarray(sin)[0], 0.0, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cos)[0], 1.0, atol=2e-4)
