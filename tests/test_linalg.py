"""C3 validation: deferred-shift fixed-point matmul vs NumPy-int64 oracle
(paper §3.3, Listing 3) and the rounding-event reduction claim (Eq. 18)."""

import numpy as np
import pytest
from _pbt import given, settings, strategies as st

from repro.core import linalg as la
from repro.core.qformat import Q16_16, from_fixed, to_fixed


def numpy_oracle_deferred(a_q, b_q, tile_k=32, rounding=True):
    """Listing 3 semantics in NumPy int64: per-K-tile 64-bit accumulate,
    ONE shift per tile, int32 (wrapping/saturating) combine."""
    a = a_q.astype(np.int64)
    b = b_q.astype(np.int64)
    M, K = a.shape
    N = b.shape[1]
    c = np.zeros((M, N), np.int64)
    for k0 in range(0, K, tile_k):
        acc = a[:, k0 : k0 + tile_k] @ b[k0 : k0 + tile_k, :]  # int64 exact
        if rounding:
            acc = (acc + (1 << 15)) >> 16
        else:
            acc = acc >> 16
        c = np.clip(c + acc, -(2**31), 2**31 - 1)
    return c.astype(np.int32)


def rand_q(rng, shape, scale=1.0):
    return np.asarray(to_fixed(rng.uniform(-scale, scale, shape).astype(np.float32), Q16_16))


@pytest.mark.parametrize("shape", [(4, 4, 4), (8, 16, 8), (33, 40, 17), (64, 64, 64)])
def test_deferred_matches_numpy_oracle(rng, shape):
    M, K, N = shape
    a = rand_q(rng, (M, K))
    b = rand_q(rng, (K, N))
    got = np.asarray(la.qmatmul_deferred(a, b, tile_k=32))
    want = numpy_oracle_deferred(a, b, tile_k=32)
    np.testing.assert_array_equal(got, want)


@given(
    st.integers(1, 12), st.integers(1, 48), st.integers(1, 12),
    st.integers(1, 40), st.booleans(),
)
@settings(max_examples=25)
def test_deferred_property_shapes_tiles(m, k, n, tile_k, rounding):
    rng = np.random.default_rng(1234 + m * 1000 + k * 10 + n + tile_k)
    a = rand_q(rng, (m, k))
    b = rand_q(rng, (k, n))
    got = np.asarray(la.qmatmul_deferred(a, b, tile_k=tile_k, rounding=rounding))
    want = numpy_oracle_deferred(a, b, tile_k=tile_k, rounding=rounding)
    np.testing.assert_array_equal(got, want)


def test_error_vs_float_bound(rng):
    """For normalized operands (paper §5.4 recommendation), the deferred
    kernel's error vs float matmul is one rounding event per K-tile:
    |err| <= ceil(K/b) * 2**-17 + input-quantization term."""
    M = K = N = 64
    af = rng.uniform(-1, 1, (M, K)).astype(np.float32)
    bf = rng.uniform(-1, 1, (K, N)).astype(np.float32)
    a, b = np.asarray(to_fixed(af)), np.asarray(to_fixed(bf))
    ar, br = np.asarray(from_fixed(a)), np.asarray(from_fixed(b))  # representable
    got = np.asarray(from_fixed(la.qmatmul_deferred(a, b, tile_k=32)))
    want = ar.astype(np.float64) @ br.astype(np.float64)
    tiles = -(-K // 32)
    bound = tiles * 2.0**-17 + 1e-6
    assert np.max(np.abs(got - want)) <= bound


def test_deferred_beats_per_element_rounding(rng):
    """Paper Eq. 18: rounding events drop from b to 1 per tile; the
    accumulated error of the deferred kernel must be strictly smaller
    on average for long inner products."""
    M, K, N = 32, 256, 32
    a = rand_q(rng, (M, K), scale=0.9)
    b = rand_q(rng, (K, N), scale=0.9)
    want = (
        np.asarray(from_fixed(a)).astype(np.float64)
        @ np.asarray(from_fixed(b)).astype(np.float64)
    )
    err_def = np.abs(np.asarray(from_fixed(la.qmatmul_deferred(a, b, tile_k=256))) - want)
    err_per = np.abs(
        np.asarray(from_fixed(la.qmatmul_per_element(a, b, rounding=False))) - want
    )
    assert err_def.mean() < err_per.mean()
    assert err_def.max() <= err_per.max() + 2**-16


def test_per_element_matches_scalar_oracle(rng):
    M, K, N = 5, 7, 3
    a = rand_q(rng, (M, K))
    b = rand_q(rng, (K, N))
    got = np.asarray(la.qmatmul_per_element(a, b, rounding=False))
    a64, b64 = a.astype(np.int64), b.astype(np.int64)
    want = np.zeros((M, N), np.int64)
    for i in range(M):
        for j in range(N):
            want[i, j] = sum((a64[i, k] * b64[k, j]) >> 16 for k in range(K))
    np.testing.assert_array_equal(got, want.astype(np.int32))


def test_tile_size_derivation_paper_eq17():
    # paper: 8 KB workspace, 4-byte elements -> b=32 power of two
    # (paper uses a 2-operand budget; ours is 3-operand, same result class)
    assert la.derive_tile_size(8192 + 4096, element_bytes=4) == 32
    # TPU: ~4 MB of VMEM working budget, int8 elements, 128-aligned
    b = la.derive_tile_size(4 * 2**20, element_bytes=1, align=128)
    assert b % 128 == 0 and b >= 512


def test_identity_and_zero(rng):
    n = 16
    eye = np.asarray(to_fixed(np.eye(n, dtype=np.float32)))
    a = rand_q(rng, (n, n))
    out = np.asarray(la.qmatmul_deferred(a, eye))
    # A @ I: each output is (a_ik * 65536) >> 16 with rounding = a exactly
    np.testing.assert_array_equal(out, a)
    zero = np.zeros((n, n), np.int32)
    np.testing.assert_array_equal(np.asarray(la.qmatmul_deferred(a, zero)), zero)
