"""Serving through the paged cache pool: exactness, isolation, chunked
prefill (zero retraces), prefix sharing, capacity admission, and the
ServingConfig consolidation.

Contracts pinned here:

* a request served through the paged pool in a BATCH (mixed lengths,
  mixed levels, slot churn) emits exactly the tokens it emits served
  ALONE through a paged pool — the gather/scatter adapters preserve the
  lane-isolation contract of the contiguous engine;
* speculative serving through the paged pool equals paged vanilla f32
  (page-granular rollback is bit-exact);
* prefix sharing ON equals prefix sharing OFF token-for-token (shared
  pages are bit-identical to the pages a cold prefill would write);
* admitting a burst of mixed-length prompts triggers ZERO chunk-step
  retraces after warmup (the fixed-shape chunked-prefill contract);
* the page pool drains to empty after every request finishes, across
  slot-reuse churn;
* the deprecated config shims still construct working servers.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.configs import smoke
from repro.models import init_params
from repro.runtime.config import ServingConfig
from repro.runtime.scheduler import Request
from repro.runtime.serve import (
    BatchedServer,
    ContinuousBatchingServer,
    ContinuousServerConfig,
    ServerConfig,
)
from repro.runtime.speculative import SpeculativeConfig

MAX_LEN = 32
PROMPTS = [
    [1, 2, 3, 4, 5],
    [7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17],
    [3, 1, 4],
    [2, 7, 1, 8, 2, 8, 1, 8, 2, 8],
]


_MODELS = {}
_ALONE = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = smoke(arch)
        _MODELS[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _paged(n_slots=2, **kw):
    kw.setdefault("max_len", MAX_LEN)
    return ServingConfig(n_slots=n_slots, cache="paged", page_size=4, **kw)


def _serve_alone(arch, prompt, max_new, level):
    """Reference output: the prompt served by itself through a 1-slot
    paged server (memoized per arch — jit compiles dominate runtime)."""
    if arch not in _ALONE:
        cfg, params = _model(arch)
        _ALONE[arch] = ContinuousBatchingServer(cfg, params, _paged(n_slots=1))
    return _ALONE[arch].generate([prompt], max_new=max_new, level=level)[0]


# ---------------------------------------------------------------------------
# exactness / isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-2b", "jamba-v0.1-52b"])
def test_paged_batch_equals_alone(arch):
    """Mixed-length batch through the paged pool == each request served
    alone, across attention families (full GQA, SWA, hybrid SSM)."""
    cfg, params = _model(arch)
    srv = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    outs = srv.generate(PROMPTS, max_new=6, level="f32")
    for p, o in zip(PROMPTS, outs):
        assert o == _serve_alone(arch, p, 6, "f32")
    # every request finished -> every page returned to the free list
    for g in srv.cache_ops.groups.values():
        assert g["alloc"].live() == []


def test_paged_mixed_levels_equal_alone():
    """Per-request precision through the paged pool: each lane's output
    equals serving it alone AT ITS LEVEL (isolation holds through the
    gather/scatter path and the pristine-masked mixed-level pass)."""
    cfg, params = _model("deepseek-7b")
    srv = ContinuousBatchingServer(cfg, params, _paged(n_slots=4))
    levels = ["q16_16", "f32", "q16_16", "f32"]
    reqs = [
        Request(rid=srv.next_rid(), prompt=p, max_new=5, level=lv)
        for p, lv in zip(PROMPTS, levels)
    ]
    fins = srv.serve(reqs)
    for r, lv in zip(reqs, levels):
        assert fins[r.rid].tokens == _serve_alone("deepseek-7b", r.prompt, 5, lv)


def test_paged_speculative_equals_vanilla_f32():
    """Ladder-speculative serving through the paged pool is
    token-identical to paged vanilla f32 — k+1-row scatter including
    the rolled-back rejected rows is a bit-exact page restore."""
    cfg, params = _model("deepseek-7b")
    spec = SpeculativeConfig(k=3, max_len=MAX_LEN)
    s_spec = ContinuousBatchingServer(
        cfg, params, _paged(n_slots=2, speculative=spec)
    )
    o_spec = s_spec.generate(PROMPTS, max_new=6, speculative=True)
    s_van = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    o_van = s_van.generate(PROMPTS, max_new=6, level="f32")
    assert o_spec == o_van
    assert s_spec.stats["spec_rounds"] > 0
    for g in s_spec.cache_ops.groups.values():
        assert g["alloc"].live() == []


def test_paged_slot_churn_and_reuse():
    """Many more requests than slots: slots recycle through
    free_slot/re-admission and late requests still match serving
    alone (no residue from prior occupants' pages)."""
    cfg, params = _model("gemma2-2b")
    prompts = [[(7 * i + j) % 120 + 1 for j in range(3 + (5 * i) % 9)]
               for i in range(7)]
    srv = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    outs = srv.generate(prompts, max_new=4, level="f32")
    for p, o in zip(prompts, outs):
        assert o == _serve_alone("gemma2-2b", p, 4, "f32")
    for g in srv.cache_ops.groups.values():
        assert g["alloc"].live() == []


def test_paged_eos_mode():
    """EOS-checked serving (per-step host pull) through the paged pool:
    finishes match the contiguous engine's."""
    cfg, params = _model("deepseek-7b")
    base = ContinuousBatchingServer(
        cfg, params, ServingConfig(n_slots=2, max_len=MAX_LEN)
    )
    o_base = base.generate(PROMPTS, max_new=8, level="f32")
    eos = int(o_base[0][len(PROMPTS[0]) + 1])  # force an early EOS for req 0
    s_c = ContinuousBatchingServer(
        cfg, params, ServingConfig(n_slots=2, max_len=MAX_LEN, eos_id=eos)
    )
    s_p = ContinuousBatchingServer(
        cfg, params, _paged(n_slots=2, eos_id=eos)
    )
    assert s_c.generate(PROMPTS, max_new=8, level="f32") == \
        s_p.generate(PROMPTS, max_new=8, level="f32")


# ---------------------------------------------------------------------------
# chunked prefill: fixed shapes, zero retraces
# ---------------------------------------------------------------------------


def test_chunked_prefill_zero_retraces_across_lengths():
    """The counting hook: the chunk step traces once per ladder level
    during warmup and NEVER again, whatever prompt lengths arrive —
    the per-length retrace cost of the contiguous prefill is gone."""
    cfg, params = _model("deepseek-7b")
    srv = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    srv.generate([[1, 2, 3]], max_new=2, level="f32")  # warmup
    traced = srv._chunk_traces
    assert traced == len(srv.level_names)  # one switch trace covers all rungs
    burst = [[(i * 13 + j) % 120 + 1 for j in range(1 + i)] for i in range(10)]
    srv.generate(burst, max_new=2, level="f32")
    srv.generate(burst[::-1], max_new=2, level="q16_16")
    assert srv._chunk_traces == traced  # ZERO new traces across the burst
    # and the chunk ledger matches ceil(len/C) per admission
    C = srv.scfg.resolved_chunk
    expect = -(-3 // C) + 2 * sum(-(-len(p) // C) for p in burst)
    assert srv.stats["prefill_chunks"] == expect


def test_chunk_size_config():
    """prefill_chunk is honored (and validated: must divide max_len;
    prefix sharing pins chunk == page_size)."""
    cfg, params = _model("deepseek-7b")
    srv = ContinuousBatchingServer(
        cfg, params,
        ServingConfig(n_slots=1, max_len=MAX_LEN, cache="paged",
                      page_size=4, prefill_chunk=8),
    )
    out = srv.generate([PROMPTS[1]], max_new=4, level="f32")[0]
    assert out == _serve_alone("deepseek-7b", PROMPTS[1], 4, "f32")
    assert srv.stats["prefill_chunks"] == -(-len(PROMPTS[1]) // 8)
    with pytest.raises(ValueError, match="divide max_len"):
        ServingConfig(cache="paged", max_len=32, page_size=4, prefill_chunk=5)
    with pytest.raises(ValueError, match="prefill_chunk == page_size"):
        ServingConfig(cache="paged", max_len=32, page_size=4,
                      prefill_chunk=8, prefix_sharing=True)


# ---------------------------------------------------------------------------
# prefix sharing
# ---------------------------------------------------------------------------


def test_prefix_sharing_token_identical_and_counted():
    """Sharing ON == sharing OFF token-for-token, with hits recorded
    and fewer chunk dispatches (the reused prefix is never re-run)."""
    cfg, params = _model("deepseek-7b")
    shared = list(range(1, 13))  # 3 full pages of 4
    prompts = [shared + [50 + i, 70 + i] for i in range(4)]
    s_off = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    o_off = s_off.generate(prompts, max_new=5, level="f32")
    s_on = ContinuousBatchingServer(
        cfg, params, _paged(n_slots=2, prefix_sharing=True)
    )
    o_on = s_on.generate(prompts, max_new=5, level="f32")
    assert o_on == o_off
    assert s_on.stats["prefix_hits"] == 3         # every admission after the first
    assert s_on.stats["prefix_tokens_reused"] == 3 * 12
    assert s_on.stats["prefill_chunks"] < s_off.stats["prefill_chunks"]
    # slots drained; only prefix-cache entries keep pages resident
    g = s_on.cache_ops.groups[f"L{MAX_LEN}"]
    assert (g["table"] == 0).all()
    assert len(s_on.cache_ops.prefix) > 0
    s_on.cache_ops.prefix.drop_all()
    assert g["alloc"].live() == []


def test_prefix_sharing_speculative_still_exact():
    """Sharing + speculative composed: still equals vanilla f32."""
    cfg, params = _model("deepseek-7b")
    shared = list(range(1, 9))
    prompts = [shared + [40 + i] for i in range(3)]
    spec = SpeculativeConfig(k=2, max_len=MAX_LEN)
    s = ContinuousBatchingServer(
        cfg, params,
        _paged(n_slots=2, prefix_sharing=True, speculative=spec),
    )
    o = s.generate(prompts, max_new=5, speculative=True)
    v = ContinuousBatchingServer(cfg, params, _paged(n_slots=2))
    assert o == v.generate(prompts, max_new=5, level="f32")
    assert s.stats["prefix_hits"] > 0


def test_prefix_sharing_rejected_for_unshareable_models():
    cfg, params = _model("gemma2-2b")
    with pytest.raises(ValueError, match="prefix_sharing"):
        ContinuousBatchingServer(
            cfg, params, _paged(n_slots=2, prefix_sharing=True)
        )


# ---------------------------------------------------------------------------
# capacity admission
# ---------------------------------------------------------------------------


def test_tight_pool_queues_admission_but_serves_all():
    """A page pool far smaller than slots x max_len: ``can_admit``
    holds requests in the queue instead of over-committing pages;
    every request still finishes and matches serving alone.

    Sizing: 8 usable pages; each 10-token prompt needs 3 blocks at
    admission and grows to 4 by its last decode write, so at most two
    of the four slots can be resident at once."""
    cfg, params = _model("deepseek-7b")
    scfg = ServingConfig(
        n_slots=4, max_len=MAX_LEN, cache="paged", page_size=4, n_pages=9,
    )
    srv = ContinuousBatchingServer(cfg, params, scfg)
    prompts = [[(11 * i + j) % 120 + 1 for j in range(10)] for i in range(6)]
    outs = srv.generate(prompts, max_new=4, level="f32")
    for p, o in zip(prompts, outs):
        assert o == _serve_alone("deepseek-7b", p, 4, "f32")
    for g in srv.cache_ops.groups.values():
        assert g["alloc"].live() == []
    assert srv.cache_ops.groups[f"L{MAX_LEN}"]["alloc"].high_water <= 8


# ---------------------------------------------------------------------------
# ServingConfig consolidation + deprecation shims
# ---------------------------------------------------------------------------


def test_serving_config_validation():
    with pytest.raises(ValueError, match="cache"):
        ServingConfig(cache="mmap")
    with pytest.raises(ValueError, match="divide max_len"):
        ServingConfig(cache="paged", max_len=30, page_size=4)
    with pytest.raises(ValueError, match="requires cache='paged'"):
        ServingConfig(prefill_chunk=8)
    with pytest.raises(ValueError, match="requires cache='paged'"):
        ServingConfig(prefix_sharing=True)
    with pytest.raises(ValueError, match="n_pages"):
        ServingConfig(cache="paged", max_len=32, page_size=4, n_pages=3)
    assert ServingConfig(cache="paged", page_size=8).resolved_chunk == 8
    assert ServingConfig().resolved_chunk is None


def test_deprecated_shims_warn_and_work():
    cfg, params = _model("deepseek-7b")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = ContinuousServerConfig(n_slots=2, max_len=MAX_LEN)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert isinstance(old, ServingConfig)  # pure alias
    srv_old = ContinuousBatchingServer(cfg, params, old)
    srv_new = ContinuousBatchingServer(
        cfg, params, ServingConfig(n_slots=2, max_len=MAX_LEN)
    )
    assert srv_old.generate(PROMPTS[:2], max_new=4) == \
        srv_new.generate(PROMPTS[:2], max_new=4)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        bcfg = ServerConfig(max_batch=2, max_len=MAX_LEN, max_new=4)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    srv_b = BatchedServer(cfg, params, bcfg)
    assert srv_b.scfg.n_slots == 2  # mapped through to_serving()
    srv_b2 = BatchedServer(
        cfg, params, ServingConfig(n_slots=2, max_len=MAX_LEN, max_new=4)
    )
    same_len = [[1, 2, 3], [4, 5, 6]]
    assert srv_b.generate(same_len) == srv_b2.generate(same_len)


def test_batched_server_rejects_paged():
    cfg, params = _model("deepseek-7b")
    with pytest.raises(ValueError, match="contiguous"):
        BatchedServer(cfg, params, _paged())
