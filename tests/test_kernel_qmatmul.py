"""Pallas qmatmul kernel vs NumPy-int64 oracle: shape sweeps, epilogue
modes, padding, per-channel exponents, int16-limb path, STE gradient."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.qmatmul import ops
from repro.kernels.qmatmul.qmatmul import qmatmul_kernel_call
from repro.kernels.qmatmul.ref import qmatmul_ref, quantize_pow2_ref
from repro.core.quantization import quantize_pow2


def rand_int8(rng, shape):
    return rng.integers(-127, 128, size=shape, dtype=np.int8)


SHAPES = [
    (8, 128, 128),      # minimal tile
    (16, 256, 128),
    (128, 128, 256),
    (100, 200, 300),    # non-multiples: exercises padding
    (1, 128, 128),      # single row
    (257, 129, 511),    # awkward primes
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("epilogue", ["int32", "q16", "float"])
def test_kernel_matches_oracle(rng, shape, epilogue):
    M, K, N = shape
    a = rand_int8(rng, (M, K))
    b = rand_int8(rng, (K, N))
    ea = np.int32(-7)
    eb = rng.integers(-9, -3, size=(N,), dtype=np.int32)
    got = np.asarray(
        qmatmul_kernel_call(a, b, ea, eb, bm=128, bn=128, bk=128, epilogue=epilogue)
    )
    want = qmatmul_ref(a, b, ea, eb, epilogue=epilogue)
    if epilogue == "float":
        np.testing.assert_allclose(got, want, rtol=1e-6)
    else:
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 256), (512, 512, 512)])
def test_block_shape_sweep(rng, blocks):
    bm, bn, bk = blocks
    M, K, N = 300, 700, 260
    a = rand_int8(rng, (M, K))
    b = rand_int8(rng, (K, N))
    ea = np.int32(-6)
    eb = np.full((N,), -7, np.int32)
    got = np.asarray(qmatmul_kernel_call(a, b, ea, eb, bm=bm, bn=bn, bk=bk, epilogue="int32"))
    want = qmatmul_ref(a, b, ea, eb, epilogue="int32")
    np.testing.assert_array_equal(got, want)


def test_accumulation_exactness_long_k(rng):
    """K=4096 worst-case int8 products must accumulate exactly (the
    paper's widened-accumulator guarantee, MXU edition)."""
    M, K, N = 8, 4096, 128
    a = np.full((M, K), 127, np.int8)
    b = np.full((K, N), 127, np.int8)
    got = np.asarray(
        qmatmul_kernel_call(a, b, np.int32(0), np.zeros((N,), np.int32), epilogue="int32")
    )
    assert got[0, 0] == 127 * 127 * K  # 66 060 288 < 2**31, exact
    np.testing.assert_array_equal(got, np.full((M, N), 127 * 127 * K, np.int32))


def test_float_path_quantization_error_bound(rng):
    """End-to-end fp->int8->fp error: per-channel W8A8 with pow2 scales
    has elementwise-bounded error ~ K * q_err terms; check against a
    loose analytic envelope and against the float64 reference."""
    M, K, N = 64, 512, 64
    a = rng.uniform(-1, 1, (M, K)).astype(np.float32)
    b = rng.uniform(-1, 1, (K, N)).astype(np.float32)
    got = np.asarray(ops.qmatmul(a, b))
    want = a.astype(np.float64) @ b.astype(np.float64)
    # int8 grid: step = 2**e <= amax/2**6; rel err per product ~ 2**-7
    err = np.abs(got - want).max()
    scale = np.abs(want).max()
    assert err < 0.02 * scale + 0.05, err


def test_quantize_matches_ref(rng):
    x = rng.uniform(-3, 3, (64, 96)).astype(np.float32)
    qt = quantize_pow2(x, bits=8, axis=1)
    q_ref, e_ref = quantize_pow2_ref(x, bits=8, axis=1)
    np.testing.assert_array_equal(np.asarray(qt.q), q_ref)
    np.testing.assert_array_equal(np.asarray(qt.exp).reshape(-1), e_ref.reshape(-1))


def test_int16_limb_composition_exact(rng):
    """The two-pass hi/lo limb composition (paper §8.1) must reproduce
    the int16 x int8 integer product EXACTLY — the limbs, zero-point
    correction and shift-combine introduce no error at all."""
    M, K, N = 32, 256, 32
    a = (rng.uniform(-1, 1, (M, K)) ** 3 * 100).astype(np.float32)
    b = rng.uniform(-1, 1, (K, N)).astype(np.float32)
    got = np.asarray(ops.qmatmul_int16(a, b))
    q16, e16 = quantize_pow2_ref(a, bits=16, axis=None)
    q8, e8 = quantize_pow2_ref(b, bits=8, axis=1)
    acc = q16.astype(np.int64) @ q8.astype(np.int64)
    want = acc.astype(np.float64) * np.exp2(float(e16) + e8.reshape(1, -1).astype(np.float64))
    np.testing.assert_allclose(got, want.astype(np.float32), rtol=1e-6)


def test_int16_limb_path_beats_int8(rng):
    """W8A16 is strictly more accurate than W8A8 on wide-dynamic-range
    activations (weight error, still int8, bounds the gain)."""
    M, K, N = 32, 256, 32
    a = (rng.uniform(-1, 1, (M, K)) ** 3 * 100).astype(np.float32)
    b = rng.uniform(-1, 1, (K, N)).astype(np.float32)
    want = a.astype(np.float64) @ b.astype(np.float64)
    err8 = np.abs(np.asarray(ops.qmatmul(a, b)) - want).mean()
    err16 = np.abs(np.asarray(ops.qmatmul_int16(a, b)) - want).mean()
    assert err16 < err8 * 0.8, (err16, err8)


def test_qdot_ste_gradient(rng):
    """STE: gradients flow as if the matmul were exact float."""
    a = rng.uniform(-1, 1, (16, 64)).astype(np.float32)
    b = rng.uniform(-1, 1, (64, 32)).astype(np.float32)

    def loss_q(a, b):
        return jnp.sum(ops.qdot_ste(a, b) ** 2)

    def loss_f(a, b):
        return jnp.sum(jnp.matmul(a, b) ** 2)

    ga_q, gb_q = jax.grad(loss_q, argnums=(0, 1))(a, b)
    ga_f, gb_f = jax.grad(loss_f, argnums=(0, 1))(a, b)
    # direction agreement (forward uses quantized out, backward exact)
    cos = lambda x, y: float(
        jnp.vdot(x, y) / (jnp.linalg.norm(x) * jnp.linalg.norm(y) + 1e-9)
    )
    assert cos(ga_q, ga_f) > 0.99
    assert cos(gb_q, gb_f) > 0.99


def test_rounding_events_deferred_not_per_product(rng):
    """The kernel's q16 epilogue must equal ONE final rounding of the
    exact accumulation — not the accumulation of per-product roundings."""
    M, K, N = 16, 512, 128
    a = rand_int8(rng, (M, K))
    b = rand_int8(rng, (K, N))
    ea, eb = np.int32(-8), np.full((N,), -8, np.int32)
    got = np.asarray(qmatmul_kernel_call(a, b, ea, eb, epilogue="q16"))
    acc = a.astype(np.int64) @ b.astype(np.int64)
    s = int(ea) + eb[None, :] + 16  # = 0 here: exact left-shift-by-zero
    want = (acc << 0).astype(np.int32)
    np.testing.assert_array_equal(got, want)
