"""Flash-attention Pallas kernel vs NumPy softmax oracle: shape/dtype
sweeps, causal + sliding-window masks, GQA head repetition, and
agreement with the model's chunked-attention path."""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flashattn import ops
from repro.kernels.flashattn.flashattn import flash_attention_call
from repro.kernels.flashattn.ref import attention_ref


def rand(rng, shape, dtype=np.float32):
    return rng.normal(0, 1, shape).astype(dtype)


CASES = [
    # (BH, S, Skv, D, Dv, causal, window)
    (2, 256, 256, 64, 64, True, None),
    (1, 512, 512, 128, 128, True, None),
    (3, 300, 300, 64, 64, True, None),       # padding path
    (2, 256, 256, 64, 64, True, 64),         # sliding window
    (2, 128, 128, 64, 32, True, None),       # Dv != D
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_oracle(rng, case):
    BH, S, Skv, D, Dv, causal, window = case
    q = rand(rng, (BH, S, D))
    k = rand(rng, (BH, Skv, D))
    v = rand(rng, (BH, Skv, Dv))
    got = np.asarray(
        flash_attention_call(
            q, k, v, scale=1.0 / math.sqrt(D), causal=causal, window=window,
            bq=128, bk=128,
        )
    )
    want = attention_ref(q, k, v, scale=1.0 / math.sqrt(D), causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("blocks", [(64, 128), (128, 256), (256, 256)])
def test_block_sweep_invariance(rng, blocks):
    bq, bk = blocks
    q = rand(rng, (2, 384, 64))
    k = rand(rng, (2, 384, 64))
    v = rand(rng, (2, 384, 64))
    got = np.asarray(
        flash_attention_call(q, k, v, scale=0.125, causal=True, bq=bq, bk=bk)
    )
    want = attention_ref(q, k, v, scale=0.125, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dtype_sweep(rng, dtype):
    q = jnp.asarray(rand(rng, (2, 256, 64)), dtype)
    k = jnp.asarray(rand(rng, (2, 256, 64)), dtype)
    v = jnp.asarray(rand(rng, (2, 256, 64)), dtype)
    got = np.asarray(
        flash_attention_call(q, k, v, scale=0.125, causal=True), np.float32
    )
    want = attention_ref(
        np.asarray(q, np.float32), np.asarray(k, np.float32), np.asarray(v, np.float32),
        scale=0.125, causal=True,
    )
    tol = 2e-3 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_gqa_and_model_path_agreement(rng):
    """ops.flash_attention == models.attention.chunked_attention on the
    same GQA inputs (both vs each other and vs the oracle)."""
    from repro.models.attention import chunked_attention

    B, S, H, KV, D = 2, 256, 8, 2, 64
    q = jnp.asarray(rand(rng, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rand(rng, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rand(rng, (B, S, KV, D)), jnp.float32)

    flash = np.asarray(ops.flash_attention(q, k, v, causal=True))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    chunked = np.asarray(
        chunked_attention(q, k, v, q_positions=positions, causal=True, chunk=128)
    )
    np.testing.assert_allclose(flash, chunked, atol=2e-3, rtol=2e-3)
