"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
config of each family runs one train step and one prefill+decode step
on CPU; output shapes verified, no NaNs.  FULL configs are exercised
only by the dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke
from repro.models import decode_step, init_caches, init_params, prefill_step, train_loss


def make_batch(cfg, B=2, S=32, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.modality_stub:
        batch["extra_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.stub_prefix_len, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def step(p, b):
        loss, metrics = train_loss(p, b, cfg)
        grads = jax.grad(lambda pp: train_loss(pp, b, cfg)[0])(p)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        return loss, metrics, gnorm

    loss, metrics, gnorm = jax.jit(step)(params, make_batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch
    assert float(metrics["tokens"]) == 64.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_smoke(arch):
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    caches = init_caches(cfg, B, cfg.max_seq)
    logits, caches = jax.jit(lambda p, t, c: prefill_step(p, t, c, cfg))(
        params, batch["tokens"], caches
    )
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    tok = jnp.argmax(logits, axis=-1)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    logits2, caches = jax.jit(lambda p, t, q, c: decode_step(p, t, q, c, cfg))(
        params, tok, pos, caches
    )
    assert logits2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


@pytest.mark.parametrize("arch", ["deepseek_7b", "granite_moe_3b_a800m", "mamba2_1_3b"])
def test_fast_mode_smoke(arch):
    """FAST (Q-format int8) path: one train step, finite loss close-ish
    to the precise path (quantization noise bounded)."""
    cfg = smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    lp, _ = jax.jit(lambda p, b: train_loss(p, b, cfg, mode="precise"))(params, batch)
    lf, _ = jax.jit(lambda p, b: train_loss(p, b, cfg, mode="fast"))(params, batch)
    assert np.isfinite(float(lf))
    assert abs(float(lf) - float(lp)) < 0.5, (float(lf), float(lp))


def test_full_configs_build_and_count():
    """FULL configs: spec construction only (no allocation).  Sanity on
    parameter counts vs published sizes (loose envelopes)."""
    expect = {
        "granite_moe_3b_a800m": (2.5e9, 4.5e9),
        "mixtral_8x22b": (120e9, 160e9),
        "phi3_vision_4_2b": (3.2e9, 5.5e9),
        "deepseek_7b": (6e9, 8e9),
        "minicpm3_4b": (3e9, 5.5e9),
        "command_r_35b": (30e9, 40e9),
        "gemma2_2b": (2e9, 3.5e9),
        "jamba_v01_52b": (45e9, 60e9),
        "mamba2_1_3b": (1.1e9, 1.6e9),
        "musicgen_large": (2.8e9, 3.6e9),  # musicgen-large is 3.3B
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("mixtral_8x22b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < 0.4 * total  # top-2 of 8 experts + shared
