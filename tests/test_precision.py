"""C4 validation: dispatch table, atomic O(1) switching, two-phase
barrier ordering, arbiter policy (paper §4; Table 1 'switch' row)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ArbiterConfig,
    MathEngine,
    Mode,
    PrecisionArbiter,
    Q16_16,
    from_fixed,
    to_fixed,
)
from repro.core.barrier import TwoPhaseBarrier


def test_engine_default_opset():
    eng = MathEngine(Mode.PRECISE)
    ctx = eng.ctx()
    for op in ("mul", "add", "sub", "sin", "cos", "matmul"):
        assert op in ctx


def test_r1_api_stability_across_modes(rng):
    """R1: identical call sites in both modes; results agree within the
    Q16.16 error envelope."""
    eng = MathEngine(Mode.PRECISE)
    theta = np.float32(0.7)
    precise_sin = float(eng.call("sin", theta))
    eng.set_mode(Mode.FAST)
    fast_sin = float(eng.call("sin", theta))
    assert fast_sin == pytest.approx(precise_sin, abs=8e-4)


def test_r3_switch_is_o1_no_recompile():
    """R3: after the first build, set_mode must not trace/compile.
    We verify by checking the switch latency is microseconds-scale and
    constant-ish across repeats (a retrace would be milliseconds)."""
    eng = MathEngine(Mode.PRECISE)
    # warm both contexts
    eng.set_mode(Mode.FAST)
    eng.set_mode(Mode.PRECISE)
    lat = []
    for _ in range(20):
        lat.append(eng.set_mode(Mode.FAST))
        lat.append(eng.set_mode(Mode.PRECISE))
    med = sorted(lat)[len(lat) // 2]
    assert med < 5e3, f"switch median {med:.1f}us — not O(1)"  # generous CPU bound
    assert eng.switch_stats.count == 42


def test_no_mixed_precision_state():
    """A context captured before the switch keeps its mode (immutability);
    the active context after the switch is uniformly the new mode."""
    eng = MathEngine(Mode.PRECISE)
    before = eng.ctx()
    eng.set_mode(Mode.FAST)
    after = eng.ctx()
    assert before.mode is Mode.PRECISE and after.mode is Mode.FAST
    with pytest.raises(AttributeError):
        before.mode = Mode.FAST  # frozen


def test_set_mode_same_mode_is_noop():
    eng = MathEngine(Mode.FAST)
    assert eng.set_mode(Mode.FAST) == 0.0
    assert eng.switch_stats.count == 0


def test_barrier_ordering():
    events = []

    def fake_sync():
        events.append("sync")

    b = TwoPhaseBarrier(sync_fn=fake_sync)
    x = jnp.ones((8,)) * 3  # in-flight device value

    def swap():
        events.append("swap")

    ev = b.transition(inflight=x, swap_fn=swap)
    assert events == ["sync", "swap"], "phase 1 (quiesce+agree) must precede phase 2"
    assert ev.total_s >= ev.swap_s >= 0


def test_compile_op_aot_paths():
    """AOT-compiled executables dispatch correctly in both modes."""
    eng = MathEngine(Mode.PRECISE)
    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def precise_fn(x):
        return jnp.matmul(x, x)

    def fast_fn(x):
        from repro.core.linalg import qmatmul_deferred
        from repro.core.qformat import from_fixed, to_fixed

        q = to_fixed(x)
        return from_fixed(qmatmul_deferred(q, q))

    eng.compile_op("square", {Mode.PRECISE: precise_fn, Mode.FAST: fast_fn}, spec)
    x = np.random.default_rng(0).uniform(-1, 1, (16, 16)).astype(np.float32)
    precise = np.asarray(eng.call("square", x))
    eng.set_mode(Mode.FAST)
    fast = np.asarray(eng.call("square", x))
    np.testing.assert_allclose(fast, precise, atol=1e-2)
    # executables, not traced fns: calling with a wrong shape must fail
    with pytest.raises(Exception):
        eng.call("square", np.zeros((8, 8), np.float32))


# ---------------------------------------------------------------------------
# arbiter policy
# ---------------------------------------------------------------------------


def test_arbiter_nan_fallback():
    arb = PrecisionArbiter(ArbiterConfig(cooldown_steps=0))
    assert arb.mode is Mode.FAST
    for s in range(10):
        assert arb.observe(s, loss=2.0, grad_norm=1.0) is None
    assert arb.observe(10, loss=float("nan"), grad_norm=1.0) is Mode.PRECISE
    assert arb.mode is Mode.PRECISE


def test_arbiter_spike_fallback_and_promotion():
    cfg = ArbiterConfig(spike_factor=4.0, stable_steps=8, cooldown_steps=2)
    arb = PrecisionArbiter(cfg)
    step = 0
    for _ in range(16):
        arb.observe(step, loss=1.0, grad_norm=1.0)
        step += 1
    assert arb.observe(step, loss=1.0, grad_norm=100.0) is Mode.PRECISE
    step += 1
    # healthy steps -> promotion back to FAST after stable_steps
    promoted_at = None
    for _ in range(32):
        out = arb.observe(step, loss=0.9, grad_norm=1.0)
        if out is Mode.FAST:
            promoted_at = step
            break
        step += 1
    assert promoted_at is not None


def test_arbiter_cooldown_prevents_flapping():
    cfg = ArbiterConfig(spike_factor=2.0, stable_steps=1, cooldown_steps=50)
    arb = PrecisionArbiter(cfg)
    for s in range(16):
        arb.observe(s, loss=1.0, grad_norm=1.0)
    assert arb.observe(16, loss=1.0, grad_norm=50.0) is Mode.PRECISE
    # immediate stability must NOT promote within the cooldown window
    for s in range(17, 40):
        assert arb.observe(s, loss=1.0, grad_norm=1.0) is None
