"""Precision-ladder API validation: level registry/ordering, compat
aliases (R1), per-op policies, scoped ``engine.at`` dispatch, jit-safe
``lax.switch`` dispatch with zero retraces, multi-tier arbiter
hysteresis, Q8.24 CORDIC datapaths, and the public ``div_q16`` op."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import cordic as cd
from repro.core.arbiter import ArbiterConfig, PrecisionArbiter
from repro.core.precision import (
    MODE_ALIASES,
    MathEngine,
    Mode,
    PrecisionLevel,
    PrecisionPolicy,
    ladder,
    ladder_names,
    resolve_level,
)
from repro.core.qformat import Q8_24, Q16_16, from_fixed, to_fixed

ONE24 = 1 << 24


def q24(x):
    return np.round(np.asarray(x, np.float64) * ONE24).astype(np.int32)


def f24(v):
    return np.asarray(v, np.int64) / ONE24


# ---------------------------------------------------------------------------
# registry and ordering
# ---------------------------------------------------------------------------


def test_default_ladder_order():
    names = ladder_names()
    # cheapest -> most precise; the compat aliases bracket the middle
    assert names.index("q8_8") < names.index("q16_16") < names.index("q8_24") < names.index("f32")
    for lvl in ladder():
        assert (lvl.qformat is not None) == lvl.is_fixed


def test_register_level_ordering_and_engine_pickup():
    """A level registered mid-ladder lands at the requested rank and is
    immediately addressable by engines (falling back up-ladder for ops
    it has no impls for)."""
    import repro.core.precision as precision
    from repro.core.qformat import QFormat

    name = "q4_12_test"
    assert name not in ladder_names()
    try:
        idx = ladder_names().index("q16_16")
        precision.register_level(
            PrecisionLevel(name, qformat=QFormat(4, 12, "test rung")), index=idx
        )
        names = ladder_names()
        assert names.index(name) == idx  # sits just below q16_16
        eng = MathEngine(name)
        assert eng.level.name == name and eng.mode is Mode.FAST
        # no op registers q4_12_test impls -> nearest more precise (q16_16)
        assert eng.ctx().op("sin") is eng._impls["sin"]["q16_16"]
    finally:
        del precision._LEVELS[name]


def test_resolve_level_aliases():
    assert resolve_level(Mode.FAST).name == MODE_ALIASES[Mode.FAST] == "q16_16"
    assert resolve_level(Mode.PRECISE).name == "f32"
    assert resolve_level("fast").name == "q16_16"     # mode-value strings too
    assert resolve_level("precise").name == "f32"
    assert resolve_level("q8_24").qformat is Q8_24
    lvl = resolve_level("q16_16")
    assert resolve_level(lvl) is lvl
    with pytest.raises(KeyError, match="unknown precision level"):
        resolve_level("q99_99")


def test_level_mode_projection():
    assert resolve_level("q8_8").mode is Mode.FAST
    assert resolve_level("q8_24").mode is Mode.FAST
    assert resolve_level("f32").mode is Mode.PRECISE


# ---------------------------------------------------------------------------
# compat-alias equivalence (R1): Mode.FAST === level q16_16
# ---------------------------------------------------------------------------


def test_mode_fast_is_q16_16_level():
    eng = MathEngine(Mode.FAST)
    assert eng.level.name == "q16_16" and eng.mode is Mode.FAST
    table_alias = {op: eng.ctx().op(op) for op in eng.ctx().ops}
    eng.set_level("f32")
    eng.set_level("q16_16")  # by name this time
    # identical dispatch tables: the SAME implementation objects
    for op, fn in table_alias.items():
        assert eng.ctx().op(op) is fn, op


def test_set_mode_set_level_equivalent():
    eng = MathEngine(Mode.PRECISE)
    eng.set_mode(Mode.FAST)
    table_via_mode = {op: eng.ctx().op(op) for op in eng.ctx().ops}
    eng.set_level("f32")
    eng.set_level("q16_16")
    for op, fn in table_via_mode.items():
        assert eng.ctx().op(op) is fn, op
    # same-level switches are free and uncounted
    before = eng.switch_stats.count
    assert eng.set_mode(Mode.FAST) == 0.0
    assert eng.switch_stats.count == before


def test_ladder_fallback_prefers_more_precise():
    """An op with no impl at the requested level resolves to the nearest
    MORE precise level (precision never silently degrades)."""
    eng = MathEngine("q8_8")
    # matmul has q16_16 + f32 impls; at q8_8 it must resolve up to q16_16
    assert eng.ctx().op("matmul") is eng._impls["matmul"]["q16_16"]
    eng.set_level("q8_24")
    # at q8_24, matmul resolves up to f32 (not down to q16_16)
    assert eng.ctx().op("matmul") is eng._impls["matmul"]["f32"]


# ---------------------------------------------------------------------------
# q8_24 dispatch + datapaths
# ---------------------------------------------------------------------------


def test_at_q8_24_dispatches_q8_24_cordic():
    """Acceptance: engine.at('q8_24') runs the Q8.24 CORDIC ops —
    bitwise identical to calling the Q8.24 kernel directly."""
    eng = MathEngine(Mode.PRECISE)
    theta = np.float32(0.7)
    with eng.at("q8_24"):
        got_sin = np.asarray(eng.call("sin", theta))
        got_atan2 = np.asarray(eng.call("atan2", np.float32(0.3), np.float32(0.9)))
    assert np.array_equal(got_sin, np.asarray(cd.cordic_sincos24(theta)[0]))
    assert np.array_equal(
        got_atan2, np.asarray(cd.cordic_atan2_24(np.float32(0.3), np.float32(0.9)))
    )
    assert eng.level.name == "f32"  # restored


def test_q8_24_sincos_error_bound(rng):
    """Q8.24 x 24-iteration CORDIC: |eps| <= 2e-6 (measured 8e-7 with
    2x margin) vs the Q16.16 path's ~1.5e-4."""
    t = rng.uniform(-20.0, 20.0, 5001).astype(np.float32)
    s, c = cd.cordic_sincos24(t)
    t_exact = f24(np.asarray(to_fixed(t, Q8_24), np.int64))
    assert np.max(np.abs(np.asarray(s, np.float64) - np.sin(t_exact))) <= 2e-6
    assert np.max(np.abs(np.asarray(c, np.float64) - np.cos(t_exact))) <= 2e-6


def test_q8_24_sincos_bit_exact_vs_oracle(rng):
    from repro.kernels.cordic.ref import cordic_sincos_ref

    tq = q24(rng.uniform(-6.0, 6.0, 2048))
    got_s, got_c = cd.cordic_sincos_q16(tq, iterations=24, frac_bits=24)
    want_s, want_c = cordic_sincos_ref(tq, iterations=24, frac_bits=24)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


def test_q8_24_atan2_error_bound(rng):
    y = rng.uniform(-1.0, 1.0, 4001)
    x = rng.uniform(-1.0, 1.0, 4001)
    got = f24(cd.atan2_q24(q24(y), q24(x)))
    want = np.arctan2(f24(q24(y)), f24(q24(x)))
    assert np.max(np.abs(got - want)) <= 1e-6
    # float boundary normalizes any magnitude into the Q8.24 word
    big = np.float32(3.0e4)
    got_b = float(cd.cordic_atan2_24(big, big))
    assert got_b == pytest.approx(math.pi / 4, abs=1e-6)


def test_q8_24_atan2_bit_exact_vs_oracle(rng):
    from repro.kernels.cordic.ref import atan2_ref

    y = q24(rng.uniform(-100.0, 100.0, 1024))
    x = q24(rng.uniform(-100.0, 100.0, 1024))
    got = np.asarray(cd.atan2_q24(y, x))
    np.testing.assert_array_equal(got, atan2_ref(y, x, iterations=24, frac_bits=24))


# ---------------------------------------------------------------------------
# div_q16 (ROADMAP public op)
# ---------------------------------------------------------------------------


def q16(x):
    return np.round(np.asarray(x, np.float64) * 65536.0).astype(np.int32)


def test_div_q16_error_bound(rng):
    # full-range operands PLUS a small-denominator stress batch (the
    # regime where a numerator-truncating normalization loses bits)
    num = q16(np.concatenate([
        rng.uniform(-30000.0, 30000.0, 6001),
        rng.uniform(-300.0, 300.0, 3000),
    ]))
    den = q16(np.concatenate([
        rng.uniform(-30000.0, 30000.0, 6001),
        rng.uniform(-0.05, 0.05, 3000),
    ]))
    den = np.where(den == 0, 1, den)
    got = np.asarray(cd.div_q16(num, den), np.int64) / 65536.0
    want = np.asarray(num, np.float64) / np.asarray(den, np.float64)
    ok = np.abs(want) < 32767  # below the Q16.16 saturation envelope
    err = np.abs(got - want)[ok]
    assert np.all(err <= 2.0 ** -15 * (1.0 + np.abs(want[ok])))


def test_div_q16_edges():
    assert int(cd.div_q16(np.int32(0), np.int32(0))) == 0
    assert int(cd.div_q16(q16(7.0), np.int32(0))) == (1 << 31) - 1      # +sat
    assert int(cd.div_q16(q16(-7.0), np.int32(0))) == -((1 << 31) - 1)  # -sat
    # quotient saturation: 30000 / 2^-16 overflows the envelope
    assert int(cd.div_q16(q16(30000.0), np.int32(1))) == (1 << 31) - 1
    # sign grid
    for a, b in ((10.0, 4.0), (-10.0, 4.0), (10.0, -4.0), (-10.0, -4.0)):
        got = float(from_fixed(cd.div_q16(q16(a), q16(b))))
        assert got == pytest.approx(a / b, abs=2e-4), (a, b)


def test_div_registered_in_opset_and_engine():
    from repro.core.precision import OP_SET

    assert "div" in OP_SET
    eng = MathEngine(Mode.PRECISE)
    precise = float(eng.call("div", np.float32(10.0), np.float32(4.0)))
    eng.set_mode(Mode.FAST)
    fast = float(eng.call("div", np.float32(10.0), np.float32(4.0)))
    assert precise == pytest.approx(2.5, abs=1e-6)
    assert fast == pytest.approx(2.5, abs=1e-4)


def test_div_kernel_bit_exact_vs_oracle(rng):
    from repro.kernels.cordic import ref
    from repro.kernels.cordic.universal import div_kernel_call

    for shape in ((512,), (1000,), (7,), (9, 33)):
        num = q16(rng.uniform(-20000.0, 20000.0, shape))
        den = q16(rng.uniform(-20000.0, 20000.0, shape))
        got = np.asarray(div_kernel_call(num, den))
        assert got.shape == shape and got.dtype == np.int32
        np.testing.assert_array_equal(got, ref.div_ref(num, den))


def test_div_float_boundary(rng):
    from repro.kernels.cordic import ops

    num = rng.uniform(-100.0, 100.0, (2048,)).astype(np.float32)
    den = rng.uniform(1.0, 100.0, (2048,)).astype(np.float32)
    got = np.asarray(ops.div(num, den))
    np.testing.assert_allclose(got, num / den, atol=5e-3)


# ---------------------------------------------------------------------------
# per-op policy
# ---------------------------------------------------------------------------


def test_policy_per_op_overrides():
    eng = MathEngine(Mode.FAST)
    pol = PrecisionPolicy(per_op={"sin": "q8_24", "matmul": "f32"})
    with eng.at(pol):
        ctx = eng.ctx()
        assert ctx.op("sin") is eng._impls["sin"]["q8_24"]
        assert ctx.op("matmul") is eng._impls["matmul"]["f32"]
        # unlisted ops follow the engine's current level
        assert ctx.op("sqrt") is eng._impls["sqrt"]["q16_16"]
    # policy restored (and with it the uniform q16_16 table)
    assert eng.ctx().op("sin") is eng._impls["sin"]["q16_16"]


def test_policy_default_pins_all_ops():
    eng = MathEngine(Mode.PRECISE)
    pol = PrecisionPolicy(default="q16_16", per_op={"atan2": "q8_24"})
    with eng.at(pol):
        assert eng.ctx().op("sqrt") is eng._impls["sqrt"]["q16_16"]
        assert eng.ctx().op("atan2") is eng._impls["atan2"]["q8_24"]
    assert eng.ctx().op("sqrt") is eng._impls["sqrt"]["f32"]


def test_policy_accepts_mode_aliases_and_is_hashable():
    pol = PrecisionPolicy(default=Mode.FAST, per_op={"sin": Mode.PRECISE})
    assert pol.default == "q16_16"
    assert pol.level_for("sin", "q16_16") == "f32"
    assert pol.level_for("cos", "q8_24") == "q16_16"  # default wins
    assert "sin" in pol and "cos" not in pol
    hash(pol)  # context-cache key


# ---------------------------------------------------------------------------
# scoped dispatch
# ---------------------------------------------------------------------------


def test_at_scoping_and_nesting():
    eng = MathEngine(Mode.PRECISE)
    assert eng.level.name == "f32"
    with eng.at("q16_16"):
        assert eng.level.name == "q16_16"
        with eng.at("q8_24"):
            assert eng.level.name == "q8_24"
            with eng.at(Mode.PRECISE):
                assert eng.level.name == "f32"
            assert eng.level.name == "q8_24"
        assert eng.level.name == "q16_16"
    assert eng.level.name == "f32"


def test_at_restores_on_exception():
    eng = MathEngine(Mode.PRECISE)
    with pytest.raises(RuntimeError):
        with eng.at("q16_16"):
            raise RuntimeError("boom")
    assert eng.level.name == "f32"


def test_at_switches_are_o1_reference_swaps():
    """Scoped entry/exit after warmup must be microseconds-scale —
    contexts are cached, so entering a scope never rebuilds tables."""
    eng = MathEngine(Mode.PRECISE)
    with eng.at("q8_24"):
        pass  # warm the context cache
    lat = []
    for _ in range(20):
        t0 = eng.switch_stats.count
        with eng.at("q8_24"):
            lat.append(eng.switch_stats.last_latency_us)
        assert eng.switch_stats.count == t0 + 2  # enter + exit
    med = sorted(lat)[len(lat) // 2]
    assert med < 5e3, f"scoped switch median {med:.1f}us — not O(1)"


def test_context_is_immutable_and_carries_level():
    eng = MathEngine("q8_24")
    ctx = eng.ctx()
    assert ctx.level.name == "q8_24" and ctx.mode is Mode.FAST
    with pytest.raises(AttributeError):
        ctx.level = resolve_level("f32")


# ---------------------------------------------------------------------------
# jit-safe functional dispatch: level changes with ZERO retraces
# ---------------------------------------------------------------------------


def test_switched_dispatch_zero_retrace():
    eng = MathEngine(Mode.FAST)
    traces = []

    def probe(fn, tag):
        def wrapped(*args):
            traces.append(tag)  # appended once per TRACE, not per call
            return fn(*args)
        return wrapped

    eng.register(
        "sin",
        q16_16=probe(lambda t: cd.cordic_sincos(t)[0], "q16_16"),
        q8_24=probe(lambda t: cd.cordic_sincos24(t)[0], "q8_24"),
        f32=probe(jnp.sin, "f32"),
    )
    dispatch, names = eng.switched("sin", levels=("q16_16", "q8_24", "f32"))
    step = jax.jit(dispatch)
    x = jnp.float32(0.5)

    out0 = step(jnp.int32(0), x)
    first_traces = list(traces)
    # lax.switch traces every branch exactly once at first compilation
    assert sorted(first_traces) == ["f32", "q16_16", "q8_24"]

    # level changes = data, not code: NO new traces, results move
    out1 = step(jnp.int32(1), x)
    out2 = step(jnp.int32(2), x)
    assert traces == first_traces, "level switch retraced the step"
    assert float(out0) == pytest.approx(math.sin(0.5), abs=8e-4)
    assert float(out1) == pytest.approx(math.sin(0.5), abs=2e-6)
    assert float(out2) == pytest.approx(math.sin(0.5), abs=1e-7)
    # the jit cache compiled ONE executable for all three levels
    assert step._cache_size() == 1


def test_level_index_tracks_engine_level():
    eng = MathEngine(Mode.FAST)
    _, names = eng.switched("sin", levels=("q16_16", "q8_24", "f32"))
    assert eng.level_index(names) == 0
    eng.set_level("q8_24")
    assert eng.level_index(names) == 1
    eng.set_mode(Mode.PRECISE)
    assert eng.level_index(names) == 2
    # absent level maps to the nearest more precise entry
    eng.set_level("q8_8")
    assert eng.level_index(("q16_16", "f32")) == 0
    eng.set_level("q8_24")
    assert eng.level_index(("q16_16", "f32")) == 1


def test_trainer_jit_switch_zero_retrace(tmp_path):
    """The trainer's jit_switch path: one executable, level moves by
    traced index mid-run with no recompilation."""
    from repro.configs import smoke
    from repro.runtime.train_loop import Trainer, TrainerConfig

    cfg = smoke("deepseek_7b")
    t = Trainer(cfg, TrainerConfig(
        total_steps=4, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=100,
        start_mode=Mode.PRECISE, jit_switch=True,
    ))
    t.run()
    assert t._switched_step._cache_size() == 1
    t.engine.set_mode(Mode.FAST)
    t.start_step, t.tcfg.total_steps = 4, 8
    out = t.run()
    assert t._switched_step._cache_size() == 1, "level switch recompiled the step"
    modes = {h["mode"] for h in out["history"]}
    assert modes == {"fast", "precise"} and np.isfinite(out["final_loss"])


# ---------------------------------------------------------------------------
# multi-tier arbiter hysteresis
# ---------------------------------------------------------------------------

LADDER4 = ("q8_8", "q16_16", "q8_24", "f32")


def _warm(arb, steps, start=0):
    for s in range(start, start + steps):
        arb.observe(s, loss=1.0, grad_norm=1.0)
    return start + steps


def test_arbiter_multi_tier_step_up_one_rung():
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=4.0, cooldown_steps=0, ladder=LADDER4, start_mode="q8_8",
    ))
    step = _warm(arb, 16)
    assert arb.observe(step, loss=1.0, grad_norm=100.0) == "q16_16"
    assert arb.rung == 1
    step = _warm(arb, 16, step + 1)
    assert arb.observe(step, loss=1.0, grad_norm=100.0) == "q8_24"
    step = _warm(arb, 16, step + 1)
    assert arb.observe(step, loss=1.0, grad_norm=100.0) == "f32"
    # at the top: further spikes have nowhere to go
    step = _warm(arb, 16, step + 1)
    assert arb.observe(step, loss=1.0, grad_norm=100.0) is None
    assert arb.mode == "f32"


def test_arbiter_nonfinite_jumps_to_top():
    arb = PrecisionArbiter(ArbiterConfig(
        cooldown_steps=10**6, ladder=LADDER4, start_mode="q8_8",
    ))
    step = _warm(arb, 10)
    arb._last_switch_step = step - 1  # mid-cooldown by construction
    assert arb.observe(step, loss=float("nan"), grad_norm=1.0) == "f32"
    assert arb.rung == len(LADDER4) - 1
    assert arb.decisions[-1][2] == "non-finite"


def test_arbiter_multi_tier_step_down_one_rung():
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=4.0, stable_steps=4, cooldown_steps=0,
        ladder=LADDER4, start_mode="f32",
    ))
    step = 0
    downs = []
    for _ in range(30):
        rec = arb.observe(step, loss=1.0, grad_norm=1.0)
        if rec is not None:
            downs.append(rec)
        step += 1
    assert downs[:3] == ["q8_24", "q16_16", "q8_8"]
    assert arb.rung == 0


def test_arbiter_binary_ladder_compat():
    """The default config still speaks Mode (identity comparisons)."""
    arb = PrecisionArbiter(ArbiterConfig(cooldown_steps=0))
    assert arb.mode is Mode.FAST and arb.ladder == (Mode.FAST, Mode.PRECISE)
    step = _warm(arb, 10)
    assert arb.observe(step, loss=float("nan"), grad_norm=1.0) is Mode.PRECISE
    assert arb.mode is Mode.PRECISE


def test_arbiter_rejects_start_outside_ladder():
    with pytest.raises(ValueError, match="not in the ladder"):
        PrecisionArbiter(ArbiterConfig(ladder=("q16_16", "f32"), start_mode="q8_8"))


def test_trainer_syncs_arbiter_start_to_engine_level(tmp_path):
    """The trainer's arbiter starts at the rung the ENGINE starts at —
    and a start level outside the arbiter ladder is a loud error, not a
    silent demotion on the first recommendation."""
    from repro.configs import smoke
    from repro.runtime.train_loop import Trainer, TrainerConfig

    cfg = smoke("deepseek_7b")
    t = Trainer(cfg, TrainerConfig(
        total_steps=1, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=100,
        start_mode=Mode.PRECISE, use_arbiter=True,  # arbiter default starts FAST
    ))
    assert t.arbiter.mode is Mode.PRECISE  # synced to the engine's level

    t2 = Trainer(cfg, TrainerConfig(
        total_steps=1, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=100,
        start_mode="q8_24", use_arbiter=True,
        arbiter=ArbiterConfig(ladder=LADDER4, start_mode="q8_8"),
    ))
    assert t2.arbiter.mode == "q8_24" and t2.engine.level.name == "q8_24"

    with pytest.raises(ValueError, match="not in the arbiter ladder"):
        Trainer(cfg, TrainerConfig(
            total_steps=1, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=100,
            start_mode="q8_24", use_arbiter=True,  # binary ladder: no q8_24 rung
        ))


def test_engine_accepts_arbiter_ladder_entries():
    """End-to-end: a multi-tier arbiter drives engine.set_level."""
    eng = MathEngine("q8_8")
    arb = PrecisionArbiter(ArbiterConfig(
        spike_factor=4.0, cooldown_steps=0, ladder=LADDER4, start_mode="q8_8",
    ))
    step = _warm(arb, 16)
    rec = arb.observe(step, loss=1.0, grad_norm=100.0)
    assert eng.set_level(rec) >= 0.0
    assert eng.level.name == "q16_16"
