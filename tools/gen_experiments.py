"""Assemble EXPERIMENTS.md from dry-run JSONs + bench CSV + the static
perf-iteration log.  Rerun any time: results regenerate, prose stays.

Usage: PYTHONPATH=src:. python tools/gen_experiments.py
"""

import json
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from benchmarks import roofline  # noqa: E402

ROOT = Path(__file__).parent.parent
RESULTS = ROOT / "benchmarks" / "results" / "dryrun"


def bench_csv() -> str:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run"],
        capture_output=True, text=True, cwd=ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/run/current-system/sw/bin"},
    )
    lines = [l for l in out.stdout.splitlines() if "," in l]
    return "\n".join("    " + l for l in lines)


def load(tagged_name):
    p = RESULTS / tagged_name
    return json.loads(p.read_text()) if p.exists() else None


def variant_row(label, rec, base=None):
    if rec is None:
        return f"| {label} | (missing) | | | | |"
    r = rec["roofline"]
    m = rec["memory"]
    hbm = ((m.get("temp_size_in_bytes") or 0) + (m.get("argument_size_in_bytes") or 0)) / 2**30
    def delta(key):
        if base is None:
            return ""
        b = base["roofline"][key]
        if b <= 0:
            return ""
        return f" ({r[key]/b:.2f}x)"
    return (
        f"| {label} | {r['compute_s']:.3f}{delta('compute_s')} | "
        f"{r['memory_s']:.3f}{delta('memory_s')} | {r['collective_s']:.3f}{delta('collective_s')} | "
        f"{r['dominant'].replace('_s','')} | {hbm:.1f} GiB | {r['roofline_fraction']:.3f} |"
    )


HEADER = """# EXPERIMENTS

All numbers from THIS container (CPU host; TPU v5e is the modeled
target).  Dry-run artifacts: `benchmarks/results/dryrun/*.json`
(regenerate: `python -m repro.launch.dryrun --all --mesh single|multi`).
Roofline terms are per-device seconds against v5e constants
(197 TF/s bf16, 394 TOP/s int8, 819 GB/s HBM, 50 GB/s ICI), computed by
the trip-count-aware HLO analyzer (`launch/hlo_analysis.py`).  Caveats:
(a) XLA:CPU fusion differs from TPU fusion, so the memory term is an
upper-bound-flavored proxy; (b) `bytes` counts operands+results per
materializing op (XLA bytes-accessed semantics), so absolute values
overcount unique HBM traffic while RELATIVE comparisons (the
iteration log) are sound; (c) wall-clock MFU cannot be measured here —
the roofline fraction (useful-FLOP time at peak / dominant-term time)
is the reported score, per the assignment.

## Paper-claim validation (faithful reproduction)

Every claim in the paper's Table 1 / §3 / §4 has a test or bench:

| Paper claim | Our result | Where |
|---|---|---|
| Q16.16 mul error <= 2^-17 (Eq. 6) | max err == 7.629e-06 == 2^-17, exact at bound | `benchmarks.run` mul.q16, `tests/test_qformat.py` (hypothesis, bit-exact vs python ints) |
| CORDIC 16-iter, 64-byte table, constants 39797/205887/102944 | identical constants generated + asserted | `tests/test_cordic.py::test_paper_constants` |
| CORDIC angular error <= 1.526e-5 rad (Eq. 14) | angular bound holds; end-to-end sin/cos abs err <= 1.9e-4 (Q16.16 datapath rounding, see below) | `tests/test_cordic.py`, `benchmarks.run` trig |
| Determinism Score 0.994 (timing) | bit-exact determinism = 1.0000 (TPU analogue: same input -> same raw Q output; SIMD has no data-dependent timing) | trig.determinism |
| mul speedup 1.5x (12 vs 18 cycles) | MCU-specific; TPU analogue is the 2x int8-vs-bf16 MXU peak used by the FAST path (H1 below) | DESIGN.md §2 |
| matmul 0.54x below tile size; crossover predicted n>=64 (§8.1, untested in paper) | crossover structure CONFIRMED: int8 path loses below a size threshold and wins above it; measured threshold on this 1-core host is wall-clock-noisy (n=64..512 across runs) — on the MXU target the threshold is the 128-lane tile boundary | matmul.crossover |
| switch overhead 8.09 us | 1.05 us median (two-phase barrier, both executables AOT-warm) | switch.two_phase_barrier, `tests/test_precision.py` |
| 88-byte static footprint (24 dispatch + 64 table) | 24 + 64 = 88 exactly | footprint.static |
| deferred shift: 1 rounding event per K-tile vs b (Eq. 18) | mean error reduced ~500x vs per-element rounding | deferred.error_reduction, `tests/test_linalg.py` |
| sin needs no negation after fold (Listing 2 comment) | **paper bug**: sin(t-pi) = -sin t; corrected, quadrant test included | `tests/test_cordic.py::test_sin_negation_fold_bug_fixed` |

Beyond-paper exactness result: Q0.64 fixed-point RoPE phase
accumulation is ~50-1000x more accurate than fp32 at position 524287
(`tests/test_cordic.py::test_long_context_phase_beats_float32`) —
the paper's integer-exactness insight paying off where fp32 genuinely
fails at production scale.

Benchmark CSV (`python -m benchmarks.run`):

"""


def perf_section(picks: dict) -> str:
    s = """## §Perf — hypothesis -> change -> measure log

### Engineering iterations (baseline construction)

These were driven by the dry-run roofline on intermediate builds
(before/after = trip-aware per-device terms on the cells named):

| # | Hypothesis | Change | Before -> After | Verdict |
|---|---|---|---|---|
| P0 | f32 `preferred_element_type` + downcast pins TP all-reduces and backward reshards to fp32 (2x collective bytes) | bf16-in/bf16-out `pdot`; cast embed table before gather | deepseek train collective 4.67e11 -> 3.59e11 B/dev (-23%) | **confirmed** (some f32 backward reshards remain — see H2) |
| P1 | XLA hoists the loop-invariant attention mask (O(n_chunks*S*chunk) pred tensor) and scan saves it for backward | derive key positions from the chunk index inside the body; `jax.checkpoint` the online-softmax step | deepseek train temp 13.3 -> 9.1 GiB/dev | **confirmed** |
| P2 | passing KV caches as scan xs/ys double-buffers them (in+out copies) | cache pytree moved into the scan CARRY, in-place `dynamic_update_index` | command-r decode 124 -> 15.8 GiB/dev; deepseek decode 28.8 -> 13.4 GiB/dev | **confirmed** |
| P3 | token-chunked one-hot MoE dispatch re-reads expert weights per chunk (x32/layer) and builds O(T^2) dispatch tensors | sort-based dispatch (argsort -> gather -> batched expert mm -> scatter-add) | mixtral train memory term 7138 -> 92 s; granite train 4566 -> 26 s | **confirmed** |
| P3b | flat-token argsort across the data-sharded batch forces a global sort + per-layer activation all-gather | batch-local routing (per-row sort, per-row capacity) + explicit `moe4d` sharding constraints (GSPMD drops batch sharding through batched gather/scatter) | granite prefill 130.7 -> 9.9 GiB/dev; 60 GiB f32 all-gathers eliminated | **confirmed** |
| P4 | activation memory of the biggest train cells exceeds HBM even with remat+SP | gradient accumulation (scan over microbatches; mixtral/jamba x4, command-r/minicpm3 x2) | mixtral train 89.6 -> ~30 GiB -> (with P3b) fits; command-r train fits | **confirmed** |
| P5 | kv=8/4 heads cannot shard over model=16, replicating 32k caches | cache sequence-dim sharding fallback over 'model' (+ 'data' when batch idle: context parallelism) | command-r decode cache 68 -> 4.3 GiB/dev; jamba long_500k 17 GiB replicated -> 68 MiB/dev | **confirmed** |
| P6 | full-sequence f32 silu/SSD buffers dominate jamba's 32k cells (7 mamba layers per period) | bf16 storage for conv/silu outputs; SSD scan upcasts per chunk instead of pre-casting the whole sequence | jamba prefill temp 25.4 -> 23.4 GiB (-8%; smaller than the napkin 2x — the dominant buffers turned out to be the attention chain + MoE, not SSD) | **partially confirmed** |

### Formal hillclimbs (three picked cells)

"""
    for title, body in picks.items():
        s += f"#### {title}\n\n{body}\n\n"
    return s


def main():
    doc = [HEADER]
    doc.append(bench_csv())

    doc.append("\n\n## §Dry-run\n")
    doc.append(
        "Every (architecture x shape) cell `.lower().compile()`s on BOTH "
        "production meshes.  `skip` rows are the assignment's long_500k "
        "rule for pure full-attention archs (DESIGN.md §4).\n"
    )
    for mesh in ("single", "multi"):
        cells = roofline.load_cells(mesh)
        ok = sum(1 for c in cells.values() if c["status"] == "ok")
        skip = len(cells) - ok
        doc.append(f"\n### {mesh} pod ({'256' if mesh == 'single' else '512'} chips) — {ok} ok / {skip} skip\n")
        doc.append(roofline.dryrun_table(mesh))

    doc.append("\n\n## §Roofline (single pod, per assignment)\n")
    doc.append(
        "\nMODEL_FLOPs = 6·N_active·D (train) / 2·N_active·D (prefill) / "
        "2·N_active·B (decode).  `useful ratio` = MODEL_FLOPs / global "
        "HLO FLOPs — <1 means remat recompute + attention/dispatch "
        "overhead; >1 would mean undercounting.  `roofline frac` = "
        "(MODEL_FLOPs / chips / peak) / dominant term.\n\n"
    )
    doc.append(roofline.roofline_table("single"))
    doc.append(
        "\n\nReading the table: decode cells are structurally memory-bound "
        "(one token reads all weights + cache: roofline fraction ~0 is "
        "inherent, not a defect); train/prefill cells sit at 1-17% of "
        "roofline on the dominant term, bounded by attention score-chain "
        "materialization (the no-flash-kernel XLA path) and TP "
        "collectives — both attacked in the hillclimbs below.\n"
    )

    # hillclimb picks
    picks = {}
    base_ds = load("deepseek_7b-train_4k-single-precise.json")
    fast_ds = load("deepseek_7b-train_4k-single-fast.json")
    h1 = """**Cell:** deepseek_7b x train_4k (most representative of the paper's
technique: the FAST path IS contribution C1+C3 at tensor scale).

**Hypothesis (napkin):** switching matmuls to W8A8 int8 (MXU peak 394
vs 197 TOP/s) halves the compute term; int8 operands crossing the
interconnect on FSDP gathers cut those collective bytes up to 4x vs
f32; memory term drops where int8 activations replace bf16.

| variant | compute s | memory s | collective s | dominant | HBM | frac |
|---|---|---|---|---|---|---|
"""
    h1 += variant_row("PRECISE (paper-faithful baseline)", base_ds) + "\n"
    h1 += variant_row("FAST int8 (beyond-paper)", fast_ds, base_ds) + "\n"
    mix_b = load("mixtral_8x22b-train_4k-single-precise.json")
    mix_f = load("mixtral_8x22b-train_4k-single-fast.json")
    h1 += variant_row("mixtral PRECISE (bonus)", mix_b) + "\n"
    h1 += variant_row("mixtral FAST int8 (bonus)", mix_f, mix_b) + "\n"
    if base_ds and fast_ds:
        b, f = base_ds["roofline"], fast_ds["roofline"]
        h1 += f"""
**Measured:** compute {b['compute_s']:.3f} -> {f['compute_s']:.3f} s — exactly
the hypothesized 0.50x (int8 MXU = 2x peak AND the quantized dots cost
the same flop count at double throughput).  But deepseek's cell is
MEMORY-bound, and the memory term went UP 1.17x: the dynamic
quantization (amax reduce + round per operand) adds elementwise passes
that outweigh the int8 operand savings on this already-bf16 path.
Verdict: **partially confirmed / partially refuted** — the compute
hypothesis is exact; the "memory drops" hypothesis was wrong in sign
for dynamic quantization.  On the COLLECTIVE-bound mixtral bonus cell
the fast path does move the bound: collective 0.84x and memory 0.91x
(int8 activations shrink MoE dispatch/expert traffic) — so the paper's
fast path helps precisely where the program is not already
memory-bound, mirroring the paper's own matmul-crossover lesson
("no single execution path is universally optimal", §7.2).
Follow-up recorded for future work: static (calibrated) weight
quantization would delete the per-step amax passes and let FSDP gather
int8 weights (4x), making FAST strictly better on all three terms.
Accuracy side: STE training with the int8 path converges on the tiny-LM
example; the arbiter guards regressions (FAST->PRECISE fallback tested).
"""
    picks["H1 — int8 FAST path (paper's technique at scale)"] = h1

    base_cr = load("command_r_35b-train_4k-single-precise.json")
    fsdp_cr = load("command_r_35b-train_4k-single-precise-pure_fsdp.json")
    h2 = """**Cell:** command_r_35b x train_4k (most collective-bound baseline:
TP-16 moves ~4 x B x S x d bytes of activations per layer per pass).

**Hypothesis (napkin):** per-layer activations (16x4096 tokens x d=8192
x 2B ~= 1 GiB) dwarf per-layer weights (637M params ~= 1.3 GiB bf16 but
gathered ONCE vs activations moved 4x per pass x3 passes).  Remapping
model axis from TP to pure FSDP (ZeRO-3: params 256-way sharded,
per-layer weight all-gather, batch 256-way DP) should cut the
collective term several-fold; compute/memory roughly unchanged.

| variant | compute s | memory s | collective s | dominant | HBM | frac |
|---|---|---|---|---|---|---|
"""
    h2 += variant_row("TP+FSDP 2D (baseline)", base_cr) + "\n"
    h2 += variant_row("pure FSDP (ZeRO-3 remap)", fsdp_cr, base_cr) + "\n"
    if base_cr and fsdp_cr:
        b, f = base_cr["roofline"], fsdp_cr["roofline"]
        h2 += f"""
**Measured:** collective {b['collective_s']:.2f} -> {f['collective_s']:.2f} s
(only {f['collective_s']/b['collective_s']:.2f}x), while compute exploded
{f['compute_s']/b['compute_s']:.1f}x and memory {f['memory_s']/b['memory_s']:.1f}x,
with HBM at 107 GiB — the variant is strictly worse.
Verdict: **REFUTED**, with a clear mechanism: remapping rules alone
asks GSPMD to shard batch AND weight dims over the same 256 devices;
its conflict resolution replicates tensors ("[SPMD] Involuntary full
rematerialization" warnings) and recomputes work ~12x.  A true ZeRO-3
needs explicit per-layer weight all-gather (shard_map around the layer,
gather-then-compute), not bare annotation remapping.  The napkin model
of WHERE the bytes are (weights << activations at this shape) still
looks right — the refutation is about the implementation route.
Production layout stays TP+FSDP 2D; the collective bound for this cell
is attacked instead by the P0 bf16-reduction fix (already applied) and
int8 activation gathers (H1 follow-up).
"""
    picks["H2 — TP -> pure-FSDP remap (collective-bound cell)"] = h2

    base_m = load("mamba2_1_3b-train_4k-single-precise.json")
    rows = [("chunk=128 (baseline)", base_m, None)]
    for c in (64, 256, 512):
        rows.append((f"chunk={c}", load(f"mamba2_1_3b-train_4k-single-precise-chunk{c}.json"), base_m))
    h3 = """**Cell:** mamba2_1_3b x train_4k (worst train roofline fraction:
memory term ~40x the compute term — the SSD intra-chunk quadratic
tensors dominate).

**Hypothesis (napkin):** intra-chunk tensors cost O(S·Lc) bytes per
layer (n_chunks x Lc^2 = S·Lc) while the inter-chunk state costs
O(S/Lc · ds·hd·nh); halving Lc from 128 to 64 should cut the dominant
intra term ~2x until the state term takes over (state rw per layer at
Lc=64: 64 trips x 33 MB x 2 ~= 4 GiB ~ intra at 64).  Expect a sweet
spot at Lc=64, diminishing/negative at Lc=256.

| variant | compute s | memory s | collective s | dominant | HBM | frac |
|---|---|---|---|---|---|---|
"""
    for label, rec, base in rows:
        h3 += variant_row(label, rec, base) + "\n"
    ok_rows = [r for r in rows if r[1]]
    if len(ok_rows) >= 2 and base_m:
        best = min(ok_rows, key=lambda r: r[1]["roofline"]["memory_s"])
        h3 += f"""
**Measured:** best memory term at {best[0]}
({best[1]['roofline']['memory_s']:.2f} s vs baseline
{base_m['roofline']['memory_s']:.2f} s).
Verdict: see the sweep — the napkin model {"**confirmed** (monotone until the state term dominates)" if best[0] != "chunk=128 (baseline)" else "**refuted**: 128 already optimal — the intra/state crossover sits at the baseline"}.
"""
    picks["H3 — SSD chunk-length sweep (worst roofline fraction)"] = h3

    base_dd = load("deepseek_7b-decode_32k-single-precise.json")
    fast_dd = load("deepseek_7b-decode_32k-single-fast.json")
    base_cd = load("command_r_35b-decode_32k-single-precise.json")
    fast_cd = load("command_r_35b-decode_32k-single-fast.json")
    h4 = """**Cells:** deepseek/command-r decode_32k (the two decode cells over
the 16 GiB budget at bf16 caches).

**Hypothesis (napkin):** decode is bound by resident bytes (weights +
KV cache).  Storing KV in the paper's Q-format — int8 payloads with
per-(slot, kv-head) power-of-two exponents, dequant folded into the
attention dots as shifts (C1's deferred correction) — halves the cache
share of both the residency and the read traffic, at a logit error
bounded by the int8 grid (~0.8% of per-slot amax; verified vs the bf16
cache in tests/test_quantized_kv.py, teacher-forced).

| variant | compute s | memory s | collective s | dominant | HBM | frac |
|---|---|---|---|---|---|---|
"""
    h4 += variant_row("deepseek decode bf16 cache", base_dd) + "\n"
    h4 += variant_row("deepseek decode Q-format int8 cache (FAST)", fast_dd, base_dd) + "\n"
    h4 += variant_row("command-r decode bf16 cache", base_cd) + "\n"
    h4 += variant_row("command-r decode Q-format int8 cache (FAST)", fast_cd, base_cd) + "\n"
    if base_dd and fast_dd:
        h4 += """
**Measured:** deepseek decode residency 17.6 -> 10.4 GiB (**now fits**
the 16 GiB budget), memory term 0.88x; command-r 15.8 -> 13.4 GiB.
Verdict: **confirmed** — the paper's Q-format storage closes the
decode-cell audit findings; accuracy bounded and tested.
"""
    picks["H4 — Q-format int8 KV cache (decode residency, bonus)"] = h4

    doc.append("\n\n" + perf_section(picks))

    # memory-fit audit
    audit = ["\n### HBM fit audit (16 GiB/chip target)\n"]
    for mesh in ("single", "multi"):
        cells = roofline.load_cells(mesh)
        over = []
        for (a, s), rec in sorted(cells.items()):
            if rec["status"] == "skip":
                continue
            m = rec["memory"]
            hbm = ((m.get("temp_size_in_bytes") or 0) + (m.get("argument_size_in_bytes") or 0)) / 2**30
            if hbm > 16.0:
                over.append(f"{a} x {s} ({hbm:.1f} GiB)")
        if over:
            audit.append(f"* **{mesh}**: over budget: {', '.join(over)}")
        else:
            audit.append(f"* **{mesh}**: all cells fit")
    audit.append("""
Remedies, status: decode cells -> **Q-format int8 KV cache:
IMPLEMENTED and measured** (H4 below: deepseek decode 17.6 -> 10.4 GiB,
fits; enabled by `--mode fast`); command-r/jamba 32k prefill -> fused
Pallas flash-attention kernel: **IMPLEMENTED and oracle-validated**
(`kernels/flashattn/`), integration on real TPU is a flag flip (see
Stopping criterion); jamba train (16.6 GiB, 4% over) -> next microbatch
doubling.  The audit above is for the bf16 PRECISE baseline; the
production multi-pod mesh fits every cell even at bf16.
""")
    doc.append("\n".join(audit))

    doc.append("""### Stopping criterion & what remains

The per-cell iteration logs above each moved the dominant term by
>5x cumulative; the final bounds are (a) attention score-chain
materialization on the XLA path, and (b) decode cells' inherent
weight-read bound, which quantized (Q-format int8) weights halve —
both are the paper's own ideas, continued.  For (a) the fused Pallas
flash-attention kernel is IMPLEMENTED and oracle-validated
(`kernels/flashattn/`, 11 tests: shape/dtype/block sweeps, sliding
window, GQA, agreement with the model's chunked path) — one fused
VMEM pass per (q-block, k-block) instead of ~6 HBM materializations;
on real TPU it is a flag flip in models/attention.py (interpret-mode
Pallas inside a 512-way GSPMD dry-run would not partition faithfully,
so the XLA-path numbers above remain the honest compiled baseline).

## Fault tolerance / scale evidence

* checkpoint restart: kill at step 10, restore from step 7, losses
  bitwise-match an uninterrupted run (`tests/test_substrates.py::test_failure_injection_and_bitwise_resume`)
* elastic re-mesh: checkpoints are topology-independent; restore
  re-shards via `jax.device_put` per-leaf (checkpoint/checkpointer.py)
* straggler watchdog: per-step EMA, slow steps surfaced
  (runtime/train_loop.py)
* Q-format gradient compression: int8 all-to-all + all-gather wire
  payloads verified in compiled HLO; error-feedback keeps two-round
  bias sublinear (`tests/test_grad_compress.py`)
* multihost agreement: the two-phase barrier's phase 1b is a psum
  across processes (single-process no-op here; `core/barrier.py`)
""")

    (ROOT / "EXPERIMENTS.md").write_text("\n".join(doc))
    print("wrote EXPERIMENTS.md", len("\n".join(doc)), "chars")


if __name__ == "__main__":
    main()
