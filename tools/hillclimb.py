"""Run the three formal hillclimb variants + bonus cells."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, json, sys
from pathlib import Path
sys.path.insert(0, "src")
from repro.launch import dryrun
from repro.launch import steps
import repro.configs as configs

OUT = Path("benchmarks/results/dryrun")

def save(rec, name):
    (OUT / name).write_text(json.dumps(rec, indent=2, default=str))
    print("->", name)

# H1: deepseek fast (int8) train
rec = dryrun.run_cell("deepseek_7b", "train_4k", "single", mode="fast")
save(rec, "deepseek_7b-train_4k-single-fast.json")

# H2: command-r pure FSDP
rec = dryrun.run_cell("command_r_35b", "train_4k", "single", sharding="pure_fsdp")
save(rec, "command_r_35b-train_4k-single-precise-pure_fsdp.json")

# H3: mamba2 SSD chunk sweep (config override via steps.get_config patch)
_orig = steps.get_config
for chunk in (64, 256):
    def patched(name, _c=chunk):
        cfg = _orig(name)
        if cfg.ssm is not None:
            cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=_c))
        return cfg
    steps.get_config = patched
    rec = dryrun.run_cell("mamba2_1_3b", "train_4k", "single")
    save(rec, f"mamba2_1_3b-train_4k-single-precise-chunk{chunk}.json")
steps.get_config = _orig

# bonus: mixtral fast-mode train (paper's fast path on the biggest MoE)
rec = dryrun.run_cell("mixtral_8x22b", "train_4k", "single", mode="fast")
save(rec, "mixtral_8x22b-train_4k-single-fast.json")
