"""Diagnostic: top HLO ops by trip-multiplied bytes + top collectives
for one (arch, shape) cell. Usage:
  PYTHONPATH=src python tools/diag_hlo.py <arch> <shape> [n]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, collections
from repro.launch.mesh import make_mesh_by_name
from repro.launch.steps import build_cell
from repro.launch.hlo_analysis import (_parse_computations, _shape_bytes, _op_bytes,
    _TRIP_RE, _CALL_ATTR_RE, _COND_ATTR_RE, COLLECTIVE_OPS)

arch, shape = sys.argv[1], sys.argv[2]
topn = int(sys.argv[3]) if len(sys.argv) > 3 else 12
mesh = make_mesh_by_name("single")
jitted, args, meta = build_cell(arch, shape, mesh, "precise")
with mesh:
    compiled = jitted.lower(*args).compile()
print("memory_analysis:", {f: getattr(compiled.memory_analysis(), f, None)
      for f in ("temp_size_in_bytes", "argument_size_in_bytes")})
comps, entry = _parse_computations(compiled.as_text())
callgraph = collections.defaultdict(list)
for cname, comp in comps.items():
    for op in comp.ops:
        if op.opcode == 'while':
            t = 1
            mt = _TRIP_RE.search(op.rest)
            if mt: t = int(mt.group(1))
            for rx in (_CALL_ATTR_RE, _COND_ATTR_RE):
                mm = rx.search(op.rest)
                if mm: callgraph[cname].append((mm.group(1), t))
        elif op.opcode in ('call','conditional'):
            for callee in _CALL_ATTR_RE.findall(op.rest):
                callgraph[cname].append((callee, 1))
mults = collections.defaultdict(int)
def walk(name, m):
    mults[name] += m
    for callee, t in callgraph.get(name, []):
        walk(callee, m*t)
walk(entry, 1)
FREE = {"parameter","get-tuple-element","tuple","constant","bitcast","after-all","iota","partition-id","replica-id"}
rows_b, rows_c, big_tensors = [], [], []
for cname, comp in comps.items():
    m = mults.get(cname, 0)
    if m == 0: continue
    for op in comp.ops:
        base = op.opcode.replace('-start','')
        if base in COLLECTIVE_OPS:
            rows_c.append((_shape_bytes(op.shape)*m, base, op.shape[:70], m, cname[:30]))
        elif op.opcode not in FREE and not op.opcode.endswith('-done') and op.opcode not in ('while','call','conditional'):
            rows_b.append((_op_bytes(op, comp)*m, op.opcode, op.shape[:70], m, cname[:30]))
        sb = _shape_bytes(op.shape)
        if sb > 2**28:
            big_tensors.append((sb, op.opcode, op.shape[:75]))
rows_b.sort(reverse=True); rows_c.sort(reverse=True); big_tensors.sort(reverse=True)
print("TOP BYTES (trip-multiplied):")
for r in rows_b[:topn]: print(f"  {r[0]:.3e} {r[1]:18s} {r[2]:70s} x{r[3]} {r[4]}")
print("TOP COLLECTIVES:")
for r in rows_c[:topn]: print(f"  {r[0]:.3e} {r[1]:16s} {r[2]:70s} x{r[3]} {r[4]}")
print("BIGGEST SINGLE TENSORS:")
seen = set()
for sb, oc, sh in big_tensors:
    if sh in seen: continue
    seen.add(sh)
    print(f"  {sb/2**30:7.2f} GiB {oc:16s} {sh}")
    if len(seen) > 9: break
